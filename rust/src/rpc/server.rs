//! The threaded TCP front-end: accepts many concurrent client
//! connections, serves them from a **bounded worker pool** and drains
//! in-flight requests on shutdown.
//!
//! Shape: one acceptor thread pushes accepted connections into a bounded
//! queue; `workers` threads pop connections and serve them to completion
//! (the protocol is strictly request/response per connection, so a worker
//! owns one connection at a time). Backpressure is the queue bound: when
//! every worker is busy and the queue is full, the acceptor blocks — new
//! clients wait in the TCP accept backlog instead of the server
//! accumulating unbounded per-connection state. This is the paper's §2.2
//! module discipline applied to the network edge: the front-end only
//! talks to [`Server`], which routes read-only methods (`stat`, `load`,
//! `nodes`, `queues`) through shared database read guards — concurrent
//! workers answer them in parallel, never queued behind a scheduling
//! round — and serializes mutations behind the write lock and the
//! central automaton's event buffer.
//!
//! Graceful drain ([`RpcServer::drain`]): stop accepting, answer the
//! request each worker is currently processing, then close every
//! connection (blocked readers are unblocked by shutting down the read
//! half of their sockets, which they observe as a clean EOF). Queued but
//! never-served connections are dropped; their clients see EOF before any
//! response and know nothing was admitted.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use super::proto::{self, code};
use super::wire;
use crate::obs::metrics;
use crate::server::Server;
use crate::types::JobState;
use crate::util::Json;
use crate::Result;

/// Default front-end address, shared by [`RpcConfig::default`] and the
/// CLI client commands so `oar serve` and `oar stat` always agree.
pub const DEFAULT_ADDR: &str = "127.0.0.1:6010";

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct RpcConfig {
    /// Bind address. Use port 0 to let the OS pick (tests/benches).
    pub addr: String,
    /// Worker pool size = max connections served concurrently.
    pub workers: usize,
    /// Accepted-but-unserved connection bound; the acceptor blocks when
    /// it is reached (backpressure).
    pub queue_depth: usize,
    /// Per-connection socket timeout, applied to idle reads between
    /// requests *and* to blocked response writes. Bounds two failure
    /// modes: silent clients pinning workers forever (the pool would
    /// otherwise wedge once `workers` sockets go quiet), and a peer that
    /// stops reading stalling drain on a blocked `write`. `None` = no
    /// timeout.
    pub io_timeout: Option<Duration>,
}

impl Default for RpcConfig {
    fn default() -> Self {
        RpcConfig {
            addr: DEFAULT_ADDR.into(),
            workers: 16,
            queue_depth: 64,
            io_timeout: Some(Duration::from_secs(60)),
        }
    }
}

impl RpcConfig {
    /// Ephemeral loopback config for tests and benches.
    pub fn loopback() -> RpcConfig {
        RpcConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        }
    }

    /// Environment overrides, applied by [`RpcServer::start`] to whatever
    /// config it is given: `OAR_RPC_IO_TIMEOUT_MS` (0 = no timeout),
    /// `OAR_RPC_QUEUE` (accept-queue depth, must be > 0) and
    /// `OAR_RPC_WORKERS` (pool size, must be > 0 — more workers means
    /// more concurrent readers sharing the database read lock). They
    /// exist so a harness or CI can tighten the front-end without
    /// plumbing flags through every entry point; unset or unparsable
    /// values leave the config untouched (`docs/PROTOCOL.md` documents
    /// the defaults).
    pub fn with_env_overrides(self) -> RpcConfig {
        let io = std::env::var("OAR_RPC_IO_TIMEOUT_MS").ok();
        let queue = std::env::var("OAR_RPC_QUEUE").ok();
        let workers = std::env::var("OAR_RPC_WORKERS").ok();
        self.apply_overrides(io.as_deref(), queue.as_deref(), workers.as_deref())
    }

    /// The pure half of [`RpcConfig::with_env_overrides`] (unit-testable
    /// without touching process-global env state).
    fn apply_overrides(
        mut self,
        io_timeout_ms: Option<&str>,
        queue_depth: Option<&str>,
        workers: Option<&str>,
    ) -> RpcConfig {
        if let Some(ms) = io_timeout_ms.and_then(|v| v.trim().parse::<u64>().ok()) {
            self.io_timeout = if ms == 0 {
                None
            } else {
                Some(Duration::from_millis(ms))
            };
        }
        if let Some(depth) = queue_depth.and_then(|v| v.trim().parse::<usize>().ok()) {
            if depth > 0 {
                self.queue_depth = depth;
            }
        }
        if let Some(n) = workers.and_then(|v| v.trim().parse::<usize>().ok()) {
            // 0 would mean a pool that never serves anyone; keep the
            // same reject-don't-clamp discipline as the queue depth.
            if n > 0 {
                self.workers = n;
            }
        }
        self
    }
}

/// State shared between the acceptor, the workers and the handle.
struct Shared {
    server: Arc<Server>,
    draining: AtomicBool,
    queue: Mutex<VecDeque<TcpStream>>,
    /// Workers wait here for connections...
    queue_cv: Condvar,
    /// ...and the acceptor waits here for queue space.
    space_cv: Condvar,
    queue_depth: usize,
    io_timeout: Option<Duration>,
    /// Read-half handles of connections currently being served, so drain
    /// can EOF readers blocked between requests.
    active: Mutex<Vec<(u64, TcpStream)>>,
    next_conn: AtomicU64,
    /// Telemetry: requests answered (any outcome).
    served: AtomicU64,
    /// Telemetry: connections accepted.
    accepted_conns: AtomicU64,
}

/// The RPC front-end handle. Dropping it drains and joins all threads.
pub struct RpcServer {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `config.addr` and start serving `server` over it.
    pub fn start(server: Arc<Server>, config: RpcConfig) -> Result<RpcServer> {
        let config = config.with_env_overrides();
        anyhow::ensure!(config.workers > 0, "RpcConfig.workers must be > 0");
        anyhow::ensure!(config.queue_depth > 0, "RpcConfig.queue_depth must be > 0");
        let listener = bind_listener(config.addr.as_str())?;
        let local_addr = listener.local_addr()?;
        // Non-blocking accept so the acceptor can observe the drain flag.
        listener.set_nonblocking(true)?;

        let shared = Arc::new(Shared {
            server,
            draining: AtomicBool::new(false),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            space_cv: Condvar::new(),
            queue_depth: config.queue_depth,
            io_timeout: config.io_timeout,
            active: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(1),
            served: AtomicU64::new(0),
            accepted_conns: AtomicU64::new(0),
        });

        let acceptor = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("oar-rpc-accept".into())
                .spawn(move || accept_loop(listener, &shared))
                .expect("spawn rpc acceptor") // oarlint: allow(R5) startup-fatal by design: no acceptor, no server
        };
        let workers = (0..config.workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("oar-rpc-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn rpc worker") // oarlint: allow(R5) startup-fatal by design: a short pool would silently shrink capacity
            })
            .collect();

        Ok(RpcServer {
            local_addr,
            shared,
            acceptor: Some(acceptor),
            workers,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Telemetry: (connections accepted, requests served).
    pub fn stats(&self) -> (u64, u64) {
        (
            self.shared.accepted_conns.load(Ordering::Relaxed),
            self.shared.served.load(Ordering::Relaxed),
        )
    }

    /// Graceful shutdown: stop accepting, finish the in-flight request on
    /// every connection, close them all, join every thread. Consumes the
    /// handle and returns the final `(connections, requests)` totals —
    /// read *after* the drain, so requests answered while draining are
    /// counted. The underlying [`Server`] keeps running (checkpointing
    /// at process shutdown is the owner's job — see `cli serve`).
    pub fn drain(mut self) -> (u64, u64) {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
        self.shared.space_cv.notify_all();
        // EOF readers parked between requests; responses being written on
        // the other half still go out. Clone the handles out first: the
        // shutdown syscalls must not run under the registry lock, or
        // every worker registering/deregistering a connection stalls
        // behind this sweep (R2).
        let streams: Vec<TcpStream> = lock_sane(&self.shared.active)
            .iter()
            .filter_map(|(_, s)| s.try_clone().ok())
            .collect();
        for stream in streams {
            let _ = stream.shutdown(Shutdown::Read);
        }
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind the listening socket. Unix IPv4 addresses are bound with
/// `SO_REUSEADDR` (via the same direct-libc FFI approach as
/// [`super::signal`] — the build is offline/zero-dep): when a server is
/// restarted on its old address — or the federation harness reboots a
/// killed cluster on the same port — connections the previous instance
/// closed first sit in TIME_WAIT and would otherwise make the rebind fail
/// with `EADDRINUSE` for minutes. IPv6, non-unix targets and any FFI
/// failure fall back to a plain `TcpListener::bind`.
fn bind_listener(addr: &str) -> Result<TcpListener> {
    #[cfg(unix)]
    {
        use std::net::ToSocketAddrs;
        if let Ok(resolved) = addr.to_socket_addrs() {
            for sa in resolved {
                if let SocketAddr::V4(v4) = sa {
                    if let Some(listener) = bind_reuseaddr_v4(&v4) {
                        return Ok(listener);
                    }
                }
            }
        }
    }
    Ok(TcpListener::bind(addr)?)
}

#[cfg(unix)]
fn bind_reuseaddr_v4(sa: &std::net::SocketAddrV4) -> Option<TcpListener> {
    use std::os::unix::io::FromRawFd;

    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = if cfg!(target_os = "linux") { 1 } else { 0xffff };
    const SO_REUSEADDR: i32 = if cfg!(target_os = "linux") { 2 } else { 4 };

    /// `struct sockaddr_in`: Linux leads with `sa_family_t sin_family`
    /// (u16); the BSDs (incl. macOS) split that slot into
    /// `sin_len`/`sin_family` bytes. Port and address are in network
    /// byte order.
    #[repr(C)]
    struct SockaddrIn {
        #[cfg(not(target_os = "linux"))]
        sin_len: u8,
        #[cfg(not(target_os = "linux"))]
        sin_family: u8,
        #[cfg(target_os = "linux")]
        sin_family: u16,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, name: i32, value: *const i32, len: u32) -> i32;
        fn bind(fd: i32, addr: *const SockaddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    let addr = SockaddrIn {
        #[cfg(not(target_os = "linux"))]
        sin_len: std::mem::size_of::<SockaddrIn>() as u8,
        #[cfg(not(target_os = "linux"))]
        sin_family: AF_INET as u8,
        #[cfg(target_os = "linux")]
        sin_family: AF_INET as u16,
        sin_port: sa.port().to_be(),
        sin_addr: u32::from_ne_bytes(sa.ip().octets()),
        sin_zero: [0; 8],
    };
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return None;
        }
        let one: i32 = 1;
        if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, 4) != 0
            || bind(fd, &addr, std::mem::size_of::<SockaddrIn>() as u32) != 0
            || listen(fd, 128) != 0
        {
            close(fd);
            return None;
        }
        Some(TcpListener::from_raw_fd(fd))
    }
}

/// Poison-tolerant lock for the front-end's registry and queue mutexes.
///
/// Handler panics are already contained per-connection by the
/// `catch_unwind` in [`worker_loop`]; these mutexes are also touched
/// *outside* that fence (acceptor backpressure, drain sweep,
/// registration). `.lock().unwrap()` there would let one poisoned guard
/// cascade-kill every worker and the acceptor — exactly the silent pool
/// shrinkage the fence exists to prevent. The data under both locks is a
/// plain list (no invariant spans the panic point), so continuing with
/// the poisoned value is sound. Contrast the `db` lock, where poison
/// *propagation* is the safety mechanism (see docs/LINTS.md §R5).
fn lock_sane<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_sane`]'s condvar twin: wait without adopting poison.
fn wait_sane<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    let (guard, _timed_out) = cv
        .wait_timeout(guard, timeout)
        .unwrap_or_else(PoisonError::into_inner);
    guard
}

fn accept_loop(listener: TcpListener, shared: &Shared) {
    while !shared.draining.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Accepted sockets must block on read/write regardless of
                // the listener's non-blocking flag.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                shared.accepted_conns.fetch_add(1, Ordering::Relaxed);
                let mut q = lock_sane(&shared.queue);
                while q.len() >= shared.queue_depth && !shared.draining.load(Ordering::SeqCst) {
                    // Backpressure: block until a worker frees a slot.
                    q = wait_sane(&shared.space_cv, q, Duration::from_millis(50));
                }
                if shared.draining.load(Ordering::SeqCst) {
                    return; // drops the stream: client sees EOF
                }
                q.push_back(stream);
                drop(q);
                shared.queue_cv.notify_one();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Idle poll. 20 ms balances accept latency after an idle
                // period (bounded by one sleep; bursts queue in the TCP
                // backlog and are then accepted back-to-back) against
                // wakeup load on a long-lived idle daemon (~50/s).
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => {
                // Transient accept failure (e.g. EMFILE): back off.
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let stream = {
            let mut q = lock_sane(&shared.queue);
            loop {
                if let Some(s) = q.pop_front() {
                    shared.space_cv.notify_one();
                    break Some(s);
                }
                if shared.draining.load(Ordering::SeqCst) {
                    break None;
                }
                q = wait_sane(&shared.queue_cv, q, Duration::from_millis(100));
            }
        };
        let Some(stream) = stream else { return };
        if shared.draining.load(Ordering::SeqCst) {
            continue; // queued connection dropped during drain
        }
        // Contain handler panics (e.g. the WAL's by-design I/O-error
        // panic, or a poisoned lock behind it) to the connection: the
        // client sees EOF with no response — by the protocol contract,
        // "not admitted" — instead of the panic silently shrinking the
        // pool until the server accepts but never answers.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_connection(shared, stream)
        }));
        if result.is_err() {
            eprintln!("oar-rpc: worker caught a handler panic; connection dropped");
        }
    }
}

/// Serve one connection until the client closes, the connection errors,
/// or the server drains.
fn serve_connection(shared: &Shared, stream: TcpStream) {
    let conn_id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
    // Socket-level timeouts (shared by every cloned handle): an idle or
    // stuck peer frees this worker after `io_timeout` instead of pinning
    // it forever.
    let _ = stream.set_read_timeout(shared.io_timeout);
    let _ = stream.set_write_timeout(shared.io_timeout);
    let Ok(registry_handle) = stream.try_clone() else {
        return;
    };
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    lock_sane(&shared.active).push((conn_id, registry_handle));
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    // Close the race with drain: if the flag was set after we were popped
    // from the queue but before we registered above, the drain sweep may
    // have missed this connection — EOF our own read half so the loop
    // below cannot block on an idle client. (If the flag flips after this
    // check, the sweep sees our registry entry and EOFs it for us.)
    if shared.draining.load(Ordering::SeqCst) {
        let _ = reader.get_ref().shutdown(Shutdown::Read);
    }

    loop {
        let doc = match wire::read_frame(&mut reader) {
            Ok(Some(doc)) => doc,
            // Clean close, or drain EOF'd the read half between requests.
            Ok(None) => break,
            Err(e) => {
                if is_timeout(&e) {
                    // Idle past io_timeout (or a stalled mid-frame send):
                    // close quietly and free the worker.
                    break;
                }
                // Torn frame / bad JSON: answer best-effort (id 0 — the
                // envelope was unreadable) and cut the connection; framing
                // is unrecoverable once desynchronized.
                let resp = proto::err_response(0, code::BAD_REQUEST, &format!("bad frame: {e}"));
                let _ = wire::write_frame(&mut writer, &resp);
                break;
            }
        };
        let response = timed_dispatch(shared, &doc);
        shared.served.fetch_add(1, Ordering::Relaxed);
        match wire::write_frame(&mut writer, &response) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // The response exceeded the frame cap. Nothing of it was
                // written, so the stream is still in sync: answer with a
                // small error envelope instead of killing the connection.
                let rid = response.get("id").and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
                let resp = proto::err_response(
                    rid,
                    code::INTERNAL,
                    "response exceeds the frame cap; narrow the query (e.g. stat with a filter)",
                );
                if wire::write_frame(&mut writer, &resp).is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
        if shared.draining.load(Ordering::SeqCst) {
            break; // in-flight request answered; close out
        }
    }
    lock_sane(&shared.active).retain(|(id, _)| *id != conn_id);
}

/// Was this read/decode failure a socket timeout (idle connection)?
fn is_timeout(e: &anyhow::Error) -> bool {
    e.source()
        .and_then(|s| s.downcast_ref::<std::io::Error>())
        .map(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        })
        .unwrap_or(false)
}

/// One request through [`dispatch`] with the obs layer around it:
/// request counter, in-flight gauge, per-method latency histogram and
/// per-error-code counters. All recording happens strictly before or
/// after the dispatch — every handler acquires and releases its own
/// guards internally, so no metric call overlaps a held lock (oarlint
/// R7). The method label is read from the raw envelope best-effort: an
/// unreadable envelope lands in the `other` histogram alongside its
/// `bad_request` error count.
fn timed_dispatch(shared: &Shared, doc: &Json) -> Json {
    metrics::RPC_REQUESTS.inc();
    metrics::RPC_INFLIGHT.rise();
    let t0 = crate::obs::clock::now_us();
    let response = dispatch(shared, doc);
    let dur_us = crate::obs::clock::now_us().saturating_sub(t0);
    metrics::RPC_INFLIGHT.fall();
    let method = doc.get("method").and_then(Json::as_str).unwrap_or("");
    metrics::rpc_method_hist(method).observe(dur_us);
    if let Some(err_code) = response
        .get("err")
        .and_then(|e| e.get("code"))
        .and_then(Json::as_str)
    {
        metrics::rpc_error_counter(err_code).inc();
    }
    response
}

/// Decode the envelope and route to the matching [`Server`] command.
fn dispatch(shared: &Shared, doc: &Json) -> Json {
    let (id, method, params) = match proto::decode_request(doc) {
        Ok(t) => t,
        Err((id, code, msg)) => return proto::err_response(id, code, &msg),
    };
    if shared.draining.load(Ordering::SeqCst) {
        return proto::err_response(id, code::SHUTTING_DOWN, "server is draining");
    }
    let server = &shared.server;
    match method.as_str() {
        "ping" => proto::ok_response(
            id,
            Json::obj(vec![
                ("protocol", Json::Num(proto::PROTOCOL_VERSION as f64)),
                ("now", Json::Num(server.now() as f64)),
            ]),
        ),
        "sub" => handle_sub(server, id, &params),
        "stat" => handle_stat(server, id, &params),
        "del" => handle_del(server, id, &params),
        "hold" => handle_hold_resume(server, id, &params, true),
        "resume" => handle_hold_resume(server, id, &params, false),
        "load" => proto::ok_response(id, proto::load_to_json(&server.load_info())),
        "nodes" => {
            let nodes = server.nodes();
            proto::ok_response(
                id,
                Json::obj(vec![(
                    "nodes",
                    Json::Arr(
                        nodes
                            .into_iter()
                            .map(|(hostname, state, procs)| {
                                Json::obj(vec![
                                    ("hostname", Json::Str(hostname)),
                                    ("state", Json::Str(state)),
                                    ("nbProcs", Json::Num(procs as f64)),
                                ])
                            })
                            .collect(),
                    ),
                )]),
            )
        }
        "queues" => {
            let queues = server.queues();
            proto::ok_response(
                id,
                Json::obj(vec![(
                    "queues",
                    Json::Arr(queues.iter().map(proto::queue_to_json).collect()),
                )]),
            )
        }
        // Typed registry snapshot (see docs/OBSERVABILITY.md): the db
        // counters inside are read under one shared read guard, so this
        // probe never waits behind a scheduling round's apply phase.
        "metrics" => proto::ok_response(id, proto::metrics_to_json(&server.metrics_snapshot())),
        "events" => handle_events(server, id, &params),
        other => proto::err_response(
            id,
            code::UNKNOWN_METHOD,
            &format!("unknown method {other:?}"),
        ),
    }
}

/// `sub`: admission rules run in-process inside [`Server::submit`]; a
/// rule's `REJECT '<message>'` comes back as the `admission_rejected`
/// error with the message **verbatim**. `array > 1` is the campaign form
/// ([`Server::submit_array`], all-or-nothing).
fn handle_sub(server: &Server, id: u64, params: &Json) -> Json {
    let spec = match proto::spec_from_json(params) {
        Ok(s) => s,
        Err(e) => return proto::err_response(id, code::BAD_REQUEST, &e.to_string()),
    };
    // Like every spec field, `array` is strictly type-checked (shared
    // validator): a mistyped value must not silently submit a different
    // campaign than the user asked.
    let array = match proto::int_param(params, "array") {
        Ok(v) => v.unwrap_or(1),
        Err(e) => return proto::err_response(id, code::BAD_REQUEST, &e.to_string()),
    };
    if !(1..=100_000).contains(&array) {
        return proto::err_response(id, code::BAD_REQUEST, "array must be in 1..=100000");
    }
    let outcome = if array == 1 {
        server.submit(&spec).map(|r| r.map(|one| vec![one]))
    } else {
        server.submit_array(&spec, array as u32)
    };
    match outcome {
        Ok(Ok(ids)) => proto::ok_response(id, proto::ids_to_json(&ids)),
        Ok(Err(reason)) => proto::err_response(id, code::ADMISSION_REJECTED, &reason),
        // e.g. a stored admission rule failed to parse: surfaced, never
        // silently dropped.
        Err(e) => proto::err_response(id, code::INTERNAL, &e.to_string()),
    }
}

/// `stat`: optional WHERE filter over the raw job columns.
fn handle_stat(server: &Server, id: u64, params: &Json) -> Json {
    let filter = match params.get("filter") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(other) => {
            return proto::err_response(
                id,
                code::BAD_REQUEST,
                &format!("filter must be a string, got {other:?}"),
            )
        }
    };
    match server.stat(filter.as_deref()) {
        Ok(jobs) => proto::ok_response(
            id,
            Json::obj(vec![(
                "jobs",
                Json::Arr(jobs.iter().map(proto::job_to_json).collect()),
            )]),
        ),
        Err(e) => proto::err_response(id, code::BAD_FILTER, &e.to_string()),
    }
}

/// `del`: routed through the central automaton's event buffer
/// ([`Server::request_delete`]) so cancellation serializes with
/// scheduling rounds instead of racing them.
fn handle_del(server: &Server, id: u64, params: &Json) -> Json {
    // Reject fractional ids instead of truncating: 17.9 must not cancel
    // job 17.
    let job = match params.get("id") {
        Some(Json::Num(n)) if n.fract() == 0.0 && *n >= 0.0 => *n as i64,
        _ => {
            return proto::err_response(
                id,
                code::BAD_REQUEST,
                "del requires a non-negative integer id",
            )
        }
    };
    match server.request_delete(job as u64) {
        Ok(state) => proto::ok_response(
            id,
            Json::obj(vec![
                ("id", Json::Num(job as f64)),
                ("state", Json::Str(state.as_str().to_string())),
                ("enqueued", Json::Bool(!state.is_terminal())),
            ]),
        ),
        Err(e) => proto::err_response(id, code::NO_SUCH_JOB, &e.to_string()),
    }
}

/// `events`: tail the bounded event log (`oar events`). Read guard
/// only. Params: strict-integer `tail` (newest N, default 20),
/// optional string `kind`, strict-integer `job` — the same validation
/// discipline as `sub`/`del` (fractional numbers are rejected, never
/// truncated).
fn handle_events(server: &Server, id: u64, params: &Json) -> Json {
    let tail = match proto::int_param(params, "tail") {
        Ok(None) => 20,
        Ok(Some(n)) if (0..=1_000_000).contains(&n) => n,
        Ok(Some(_)) => {
            return proto::err_response(id, code::BAD_REQUEST, "tail must be in 0..=1000000")
        }
        Err(e) => return proto::err_response(id, code::BAD_REQUEST, &e.to_string()),
    };
    let kind = match params.get("kind") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(other) => {
            return proto::err_response(
                id,
                code::BAD_REQUEST,
                &format!("kind must be a string, got {other:?}"),
            )
        }
    };
    let job = match proto::int_param(params, "job") {
        Ok(None) => None,
        Ok(Some(n)) if n >= 0 => Some(n as u64),
        Ok(Some(_)) => {
            return proto::err_response(id, code::BAD_REQUEST, "job must be non-negative")
        }
        Err(e) => return proto::err_response(id, code::BAD_REQUEST, &e.to_string()),
    };
    let (records, total) = server.events_tail(tail as usize, kind.as_deref(), job);
    proto::ok_response(id, proto::events_to_json(&records, total))
}

/// `hold`/`resume` (`oarhold`/`oarresume`): the in-process [`Server`] API
/// has always had these; this exposes them to clients. The job id gets
/// the same strict-integer discipline as `del`. Fig. 1 only allows
/// Waiting ⇄ Hold, so targeting a job in any other state is the typed
/// `illegal_state` error, distinct from an unknown id (`no_such_job`).
fn handle_hold_resume(server: &Server, id: u64, params: &Json, hold: bool) -> Json {
    let job = match params.get("id") {
        Some(Json::Num(n)) if n.fract() == 0.0 && *n >= 0.0 => *n as u64,
        _ => {
            return proto::err_response(
                id,
                code::BAD_REQUEST,
                &format!(
                    "{} requires a non-negative integer id",
                    if hold { "hold" } else { "resume" }
                ),
            )
        }
    };
    let outcome = if hold { server.hold(job) } else { server.resume(job) };
    match outcome {
        Ok(()) => {
            // The transition target is deterministic (Waiting ⇄ Hold), so
            // report it directly: re-reading the row here would race the
            // automaton — a resumed job can already be `toLaunch` by now.
            let state = if hold { JobState::Hold } else { JobState::Waiting };
            proto::ok_response(
                id,
                Json::obj(vec![
                    ("id", Json::Num(job as f64)),
                    ("state", Json::Str(state.as_str().to_string())),
                ]),
            )
        }
        Err(e) => match e.downcast_ref::<crate::db::DbError>() {
            Some(crate::db::DbError::JobNotFound(_)) => {
                proto::err_response(id, code::NO_SUCH_JOB, &e.to_string())
            }
            Some(crate::db::DbError::IllegalTransition { .. }) => {
                proto::err_response(id, code::ILLEGAL_STATE, &e.to_string())
            }
            _ => proto::err_response(id, code::INTERNAL, &e.to_string()),
        },
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on broken expectations
mod tests {
    use super::*;

    // End-to-end server tests live in `rust/tests/rpc.rs`; here only the
    // pure dispatch pieces that need no socket.

    use crate::cluster::VirtualCluster;
    use crate::server::ServerConfig;

    fn shared() -> Arc<Shared> {
        let cluster = Arc::new(VirtualCluster::tiny(2, 1));
        let mut cfg = ServerConfig::fast(0.0);
        cfg.sched.dense_matching = false;
        Arc::new(Shared {
            server: Arc::new(Server::new(cluster, cfg)),
            draining: AtomicBool::new(false),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            space_cv: Condvar::new(),
            queue_depth: 4,
            io_timeout: None,
            active: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(1),
            served: AtomicU64::new(0),
            accepted_conns: AtomicU64::new(0),
        })
    }

    #[test]
    fn dispatch_routes_and_reports_unknown_method() {
        let shared = shared();
        let resp = dispatch(&shared, &proto::request(3, "ping", Json::Null));
        assert!(resp.get("ok").is_some(), "{resp:?}");
        assert_eq!(resp.get("id").and_then(Json::as_i64), Some(3));

        let resp = dispatch(&shared, &proto::request(4, "frobnicate", Json::Null));
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::UNKNOWN_METHOD));
    }

    #[test]
    fn dispatch_while_draining_refuses_new_work() {
        let shared = shared();
        shared.draining.store(true, Ordering::SeqCst);
        let resp = dispatch(&shared, &proto::request(1, "ping", Json::Null));
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::SHUTTING_DOWN));
    }

    #[test]
    fn env_overrides_parse_strictly() {
        let base = RpcConfig::default();
        // Unset / garbage: untouched.
        let cfg = base.clone().apply_overrides(None, None, None);
        assert_eq!(cfg.io_timeout, Some(Duration::from_secs(60)));
        assert_eq!(cfg.queue_depth, 64);
        assert_eq!(cfg.workers, 16);
        let cfg = base
            .clone()
            .apply_overrides(Some("fast"), Some("-3"), Some("many"));
        assert_eq!(cfg.io_timeout, Some(Duration::from_secs(60)));
        assert_eq!(cfg.queue_depth, 64);
        assert_eq!(cfg.workers, 16);
        // Valid values override; 0 io timeout = no timeout; 0 queue depth
        // or 0 workers would break the pool invariants and are ignored.
        let cfg = base.clone().apply_overrides(Some("1500"), Some("8"), Some("64"));
        assert_eq!(cfg.io_timeout, Some(Duration::from_millis(1500)));
        assert_eq!(cfg.queue_depth, 8);
        assert_eq!(cfg.workers, 64);
        let cfg = base.apply_overrides(Some("0"), Some("0"), Some("0"));
        assert_eq!(cfg.io_timeout, None);
        assert_eq!(cfg.queue_depth, 64);
        assert_eq!(cfg.workers, 16);
    }

    #[test]
    fn load_probe_via_dispatch() {
        let shared = shared();
        let resp = dispatch(&shared, &proto::request(1, "load", Json::Null));
        let info = proto::load_from_json(resp.get("ok").expect("ok")).unwrap();
        // The dispatch fixture is a tiny(2, 1) cluster, fully idle.
        assert_eq!(info.nodes_total, 2);
        assert_eq!(info.procs_alive, 2);
        assert_eq!(info.procs_free, 2);
        assert_eq!(info.running_jobs, 0);
    }

    #[test]
    fn metrics_and_events_via_dispatch() {
        let shared = shared();
        // Through the instrumented wrapper, so the request itself lands
        // in the registry too.
        let resp = timed_dispatch(&shared, &proto::request(1, "metrics", Json::Null));
        let snap = proto::metrics_from_json(resp.get("ok").expect("ok")).unwrap();
        assert_eq!(snap.version, crate::obs::SNAPSHOT_VERSION);
        // The db-derived counters travel with the registry catalogue.
        assert!(
            snap.counters
                .iter()
                .any(|(n, _)| n == "oar_db_events_retention_cap"),
            "merged db counters missing"
        );

        // Submit one job so the log has a SUBMISSION row, then tail it
        // with every filter at once.
        let params = Json::obj(vec![
            ("user", Json::Str("u".into())),
            ("command", Json::Str("sleep 30".into())),
        ]);
        let resp = dispatch(&shared, &proto::request(2, "sub", params));
        let ids = proto::ids_from_json(resp.get("ok").expect("ok")).unwrap();
        let resp = dispatch(
            &shared,
            &proto::request(
                3,
                "events",
                Json::obj(vec![
                    ("tail", Json::Num(5.0)),
                    ("kind", Json::Str("SUBMISSION".into())),
                    ("job", Json::Num(ids[0] as f64)),
                ]),
            ),
        );
        let (records, total) = proto::events_from_json(resp.get("ok").expect("ok")).unwrap();
        assert_eq!(total, 1);
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].kind, "SUBMISSION");
        assert_eq!(records[0].job, Some(ids[0]));

        // Mistyped params are typed errors, same discipline as `sub`.
        let resp = dispatch(
            &shared,
            &proto::request(4, "events", Json::obj(vec![("tail", Json::Num(1.5))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::BAD_REQUEST));
        let resp = dispatch(
            &shared,
            &proto::request(5, "events", Json::obj(vec![("kind", Json::Num(7.0))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::BAD_REQUEST));
    }

    #[test]
    fn hold_resume_via_dispatch() {
        let shared = shared();
        let params = Json::obj(vec![
            ("user", Json::Str("u".into())),
            ("command", Json::Str("sleep 30".into())),
            ("nbNodes", Json::Num(2.0)),
            ("maxTime", Json::Num(60.0)),
        ]);
        let resp = dispatch(&shared, &proto::request(1, "sub", params));
        let ids = proto::ids_from_json(resp.get("ok").expect("ok")).unwrap();

        // Freshly submitted jobs are Waiting; hold must land before the
        // scheduler picks the job up, so race the automaton and accept
        // either outcome — but the *typed* outcome, never a decode error.
        let resp = dispatch(
            &shared,
            &proto::request(2, "hold", Json::obj(vec![("id", Json::Num(ids[0] as f64))])),
        );
        if let Some(ok) = resp.get("ok") {
            assert_eq!(ok.get("state").and_then(Json::as_str), Some("Hold"));
            // Deterministic gate check: a second hold targets a job that
            // is now Hold, not Waiting — fig. 1 has no Hold → Hold edge,
            // so this must be the typed `illegal_state`, race-free.
            let resp = dispatch(
                &shared,
                &proto::request(6, "hold", Json::obj(vec![("id", Json::Num(ids[0] as f64))])),
            );
            let err = resp.get("err").expect("second hold must fail");
            assert_eq!(
                err.get("code").and_then(Json::as_str),
                Some(code::ILLEGAL_STATE)
            );
            let resp = dispatch(
                &shared,
                &proto::request(3, "resume", Json::obj(vec![("id", Json::Num(ids[0] as f64))])),
            );
            let ok = resp.get("ok").expect("resume ok");
            assert_eq!(ok.get("state").and_then(Json::as_str), Some("Waiting"));
        } else {
            let err = resp.get("err").expect("err");
            assert_eq!(
                err.get("code").and_then(Json::as_str),
                Some(code::ILLEGAL_STATE)
            );
        }

        // Unknown id and mistyped id: typed errors.
        let resp = dispatch(
            &shared,
            &proto::request(4, "hold", Json::obj(vec![("id", Json::Num(424242.0))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::NO_SUCH_JOB));
        let resp = dispatch(
            &shared,
            &proto::request(5, "resume", Json::obj(vec![("id", Json::Num(1.5))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::BAD_REQUEST));
    }

    #[test]
    fn sub_del_stat_via_dispatch() {
        let shared = shared();
        let params = Json::obj(vec![
            ("user", Json::Str("u".into())),
            ("command", Json::Str("sleep 30".into())),
            ("maxTime", Json::Num(60.0)),
        ]);
        let resp = dispatch(&shared, &proto::request(1, "sub", params));
        let ids = proto::ids_from_json(resp.get("ok").expect("ok")).unwrap();
        assert_eq!(ids.len(), 1);

        let resp = dispatch(
            &shared,
            &proto::request(2, "del", Json::obj(vec![("id", Json::Num(ids[0] as f64))])),
        );
        assert!(resp.get("ok").is_some(), "{resp:?}");

        let resp = dispatch(
            &shared,
            &proto::request(3, "del", Json::obj(vec![("id", Json::Num(424242.0))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::NO_SUCH_JOB));

        let filter = Json::obj(vec![("filter", Json::Str("state = 'Error'".into()))]);
        let resp = dispatch(&shared, &proto::request(4, "stat", filter));
        assert!(resp.get("ok").is_some());

        // Mistyped params must be rejected, never silently reinterpreted:
        // a fractional id would otherwise truncate onto another job, and
        // a string `array` would submit 1 job instead of a campaign.
        let resp = dispatch(
            &shared,
            &proto::request(6, "del", Json::obj(vec![("id", Json::Num(17.9))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::BAD_REQUEST));
        let params = Json::obj(vec![
            ("command", Json::Str("date".into())),
            ("array", Json::Str("10".into())),
        ]);
        let resp = dispatch(&shared, &proto::request(7, "sub", params));
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::BAD_REQUEST));
        let resp = dispatch(
            &shared,
            &proto::request(5, "stat", Json::obj(vec![("filter", Json::Str("(((".into()))])),
        );
        let err = resp.get("err").expect("err");
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code::BAD_FILTER));
    }
}
