//! Table 2 (§3.1): the functionality matrix — but *verified*, not
//! asserted: each feature row is backed by a programmatic check that
//! exercises the feature through the public API and reports pass/fail.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::VirtualCluster;
use crate::server::{Server, ServerConfig};
use crate::types::{JobKind, JobSpec, JobState};

/// One feature row of Table 2.
#[derive(Debug, Clone)]
pub struct FeatureRow {
    pub feature: &'static str,
    /// Paper's Table 2 support marks: (OpenPBS, SGE, Maui+OpenPBS, OAR).
    pub paper: (bool, bool, bool, bool),
    /// Did this repository demonstrate the feature end-to-end?
    pub demonstrated: bool,
    pub note: String,
}

fn quick_server() -> Server {
    scaled_server(0.0)
}

/// `scale > 0` makes simulated runtimes real so ordering checks are
/// deterministic (a `sleep 0.5` blocker really blocks for 500 ms).
fn scaled_server(scale: f64) -> Server {
    let cluster = Arc::new(VirtualCluster::tiny(4, 2));
    let mut cfg = ServerConfig::fast(scale);
    cfg.sched.dense_matching = false;
    Server::new(cluster, cfg)
}

/// Run every feature check; one row per Table 2 line.
pub fn verify_features() -> Vec<FeatureRow> {
    let wait = Duration::from_secs(20);
    let mut rows = Vec::new();

    // Interactive mode: submit an INTERACTIVE job; it must run.
    rows.push({
        let server = quick_server();
        let id = server
            .submit(&JobSpec {
                kind: JobKind::Interactive,
                ..JobSpec::batch("u", "date", 1, 60)
            })
            .unwrap()
            .unwrap();
        server.wait_all_terminal(wait);
        let ok = server.with_db(|db| db.job(id)).unwrap().state == JobState::Terminated;
        FeatureRow {
            feature: "Interactive mode",
            paper: (true, true, true, true),
            demonstrated: ok,
            note: "INTERACTIVE job ran to completion".into(),
        }
    });

    // Batch mode.
    rows.push({
        let server = quick_server();
        let id = server
            .submit(&JobSpec::batch("u", "date", 1, 60))
            .unwrap()
            .unwrap();
        server.wait_all_terminal(wait);
        let ok = server.with_db(|db| db.job(id)).unwrap().state == JobState::Terminated;
        FeatureRow {
            feature: "Batch mode",
            paper: (true, true, true, true),
            demonstrated: ok,
            note: "PASSIVE job ran to completion".into(),
        }
    });

    // Parallel jobs.
    rows.push({
        let server = quick_server();
        let id = server
            .submit(&JobSpec {
                weight: 2,
                ..JobSpec::batch("u", "date", 3, 60)
            })
            .unwrap()
            .unwrap();
        server.wait_all_terminal(wait);
        let (state, assigned) =
            server.with_db(|db| (db.job(id).unwrap().state, db.assigned_nodes(id)));
        FeatureRow {
            feature: "Parallel jobs support",
            paper: (true, true, true, true),
            demonstrated: state == JobState::Terminated && assigned.len() == 3,
            note: format!("3 nodes x 2 procs -> {assigned:?}"),
        }
    });

    // Multiqueues with priorities.
    rows.push({
        let server = scaled_server(1.0);
        server.with_db(|db| {
            db.add_queue(crate::types::Queue::new(
                "urgent",
                100,
                crate::types::QueuePolicyKind::FifoConservative,
            ))
        });
        // Fill the cluster, then submit to both queues; urgent must start
        // first once resources free up.
        let _fill = server
            .submit(&JobSpec::batch("x", "sleep 0.5", 4, 60))
            .unwrap()
            .unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let slow = server
            .submit(&JobSpec::batch("a", "date", 4, 60))
            .unwrap()
            .unwrap();
        let fast = server
            .submit(&JobSpec {
                queue: Some("urgent".into()),
                ..JobSpec::batch("b", "date", 4, 60)
            })
            .unwrap()
            .unwrap();
        server.wait_all_terminal(wait);
        let (s_slow, s_fast) = server.with_db(|db| {
            (
                db.job(slow).unwrap().start_time.unwrap_or(i64::MAX),
                db.job(fast).unwrap().start_time.unwrap_or(i64::MAX),
            )
        });
        FeatureRow {
            feature: "Multiqueues with priorities",
            paper: (true, true, true, true),
            demonstrated: s_fast <= s_slow,
            note: format!("urgent started at {s_fast}ms, default at {s_slow}ms"),
        }
    });

    // Resources matching.
    rows.push({
        let server = quick_server();
        let id = server
            .submit(&JobSpec {
                properties: Some("mem >= 1024".into()),
                ..JobSpec::batch("u", "date", 1, 60)
            })
            .unwrap()
            .unwrap();
        server.wait_all_terminal(wait);
        let ok = server.with_db(|db| db.job(id)).unwrap().state == JobState::Terminated;
        FeatureRow {
            feature: "Resources matching",
            paper: (true, true, true, true),
            demonstrated: ok,
            note: "properties = 'mem >= 1024' matched and ran".into(),
        }
    });

    // Admission policies.
    rows.push({
        let server = quick_server();
        server.with_db(|db| db.add_admission_rule(5, "IF user = 'evil' THEN REJECT 'no'"));
        let rejected = server
            .submit(&JobSpec {
                user: "evil".into(),
                ..JobSpec::default()
            })
            .unwrap()
            .is_err();
        FeatureRow {
            feature: "Admission policies",
            paper: (true, true, true, true),
            demonstrated: rejected,
            note: "stored rule rejected the submission".into(),
        }
    });

    // File staging — not supported by OAR in the paper either.
    rows.push(FeatureRow {
        feature: "File staging",
        paper: (true, true, true, false),
        demonstrated: false,
        note: "unsupported, as in the paper".into(),
    });

    // Jobs dependences — not supported by OAR in the paper either.
    rows.push(FeatureRow {
        feature: "Jobs dependences",
        paper: (true, true, true, false),
        demonstrated: false,
        note: "unsupported, as in the paper".into(),
    });

    // Backfilling: a short job must start before a blocked big one ends.
    rows.push({
        let server = scaled_server(1.0);
        let _running = server
            .submit(&JobSpec::batch("x", "sleep 0.6", 2, 600))
            .unwrap()
            .unwrap();
        std::thread::sleep(Duration::from_millis(100));
        // big job wants all 4 nodes -> must wait for _running
        let big = server
            .submit(&JobSpec::batch("a", "date", 4, 600))
            .unwrap()
            .unwrap();
        // small short job fits on the 2 idle nodes without delaying big
        let small = server
            .submit(&JobSpec::batch("b", "date", 2, 60))
            .unwrap()
            .unwrap();
        server.wait_all_terminal(wait);
        let (s_big, s_small) = server.with_db(|db| {
            (
                db.job(big).unwrap().start_time.unwrap_or(i64::MAX),
                db.job(small).unwrap().start_time.unwrap_or(i64::MAX),
            )
        });
        FeatureRow {
            feature: "Backfilling",
            paper: (false, false, true, true),
            demonstrated: s_small < s_big,
            note: format!("small backfilled at {s_small}ms, big at {s_big}ms"),
        }
    });

    // Reservations.
    rows.push({
        let server = quick_server();
        let id = server
            .submit(&JobSpec {
                reservation_start: Some(1), // 1s after epoch
                ..JobSpec::batch("u", "date", 2, 60)
            })
            .unwrap()
            .unwrap();
        server.wait_all_terminal(Duration::from_secs(30));
        let job = server.with_db(|db| db.job(id)).unwrap();
        let ok = job.state == JobState::Terminated
            && job.start_time.unwrap_or(0) >= 1000
            && job.reservation == crate::types::ReservationField::Scheduled;
        FeatureRow {
            feature: "Reservations",
            paper: (false, false, true, true),
            demonstrated: ok,
            note: format!(
                "reserved t=1000ms, started {:?}ms",
                job.start_time
            ),
        }
    });

    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_oar_feature_is_demonstrated() {
        for row in verify_features() {
            let oar_supported = row.paper.3;
            assert_eq!(
                row.demonstrated, oar_supported,
                "{}: {}",
                row.feature, row.note
            );
        }
    }
}
