//! Table 1 (§3.1): software complexity — source files and lines.
//!
//! The paper counts "only the files needed by the system to operate" and
//! reports OAR at 30 files / 5k lines (25k with Taktuk) against OpenPBS's
//! 350 files / 148k lines. We reproduce the *measurement procedure* on
//! this repository: count the operational core of our OAR (everything
//! except the baselines, benches and tests) and the equivalents of the
//! comparison systems we had to build in-repo (the baseline schedulers),
//! and print them next to the paper's original numbers.

use std::path::Path;

/// A counted component.
#[derive(Debug, Clone)]
pub struct Loc {
    pub name: String,
    pub files: usize,
    pub lines: usize,
    /// Lines excluding blanks and pure comment lines.
    pub code_lines: usize,
}

/// Count `.rs`/`.py` sources under `root` (recursively), excluding any
/// path containing one of `exclude` and excluding `#[cfg(test)]` tails.
pub fn count_tree(name: &str, root: &Path, exclude: &[&str]) -> Loc {
    let mut loc = Loc {
        name: name.to_string(),
        files: 0,
        lines: 0,
        code_lines: 0,
    };
    walk(root, &mut |path| {
        let p = path.to_string_lossy();
        if exclude.iter().any(|e| p.contains(e)) {
            return;
        }
        let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
        if ext != "rs" && ext != "py" {
            return;
        }
        let Ok(text) = std::fs::read_to_string(path) else {
            return;
        };
        loc.files += 1;
        // Count up to the unit-test marker: tests are not "needed by the
        // system to operate" (the paper's criterion).
        let operational: &str = text
            .split("#[cfg(test)]")
            .next()
            .unwrap_or(&text);
        loc.lines += operational.lines().count();
        loc.code_lines += operational
            .lines()
            .filter(|l| {
                let t = l.trim();
                !t.is_empty()
                    && !t.starts_with("//")
                    && !t.starts_with('#')
                    && !t.starts_with("\"\"\"")
            })
            .count();
    });
    loc
}

fn walk(dir: &Path, f: &mut impl FnMut(&Path)) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for path in paths {
        if path.is_dir() {
            walk(&path, f);
        } else {
            f(&path);
        }
    }
}

/// The paper's Table 1 (for side-by-side printing).
pub const PAPER_TABLE1: &[(&str, &str, &str, &str)] = &[
    ("OpenPBS", "C", "350", "148k"),
    ("Maui (+OpenPBS)", "C", "142", "142k (290k)"),
    ("Maui Molokini", "Java", "116", "25k"),
    ("Taktuk", "C++", "120", "20k"),
    ("OAR (+Taktuk)", "Perl", "30", "5k (25k)"),
];

/// Measure this repository's components, mirroring the paper's method.
/// `repo` is the repository root.
pub fn measure_repo(repo: &Path) -> Vec<Loc> {
    let rust = repo.join("rust/src");
    vec![
        // the operational OAR core (what the paper counts for OAR)
        count_tree(
            "OAR core (this repo)",
            &rust,
            &["baselines.rs", "bench/", "cli/"],
        ),
        // the launcher substrate (the paper counts Taktuk separately)
        count_tree("launcher (Taktuk-like)", &rust.join("launcher"), &[]),
        // the baseline schedulers we had to build for §3.2
        count_tree(
            "baseline schedulers",
            &rust.join("sched"),
            &["gantt.rs", "meta.rs", "policies.rs", "mod.rs"],
        ),
        // the L1/L2 compile path
        count_tree("jax/pallas compile path", &repo.join("python/compile"), &[]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_repo() {
        let repo = Path::new(env!("CARGO_MANIFEST_DIR"));
        let rows = measure_repo(repo);
        assert_eq!(rows.len(), 4);
        let core = &rows[0];
        assert!(core.files > 10, "core files: {}", core.files);
        assert!(core.lines > 1000, "core lines: {}", core.lines);
        assert!(core.code_lines < core.lines);
        // baselines are a small fraction of the core — the paper's
        // low-complexity claim, reproduced structurally.
        let baselines = &rows[2];
        assert!(baselines.lines * 5 < core.lines);
    }

    #[test]
    fn exclusions_apply() {
        let repo = Path::new(env!("CARGO_MANIFEST_DIR"));
        let all = count_tree("all", &repo.join("rust/src"), &[]);
        let no_db = count_tree("no-db", &repo.join("rust/src"), &["db/"]);
        assert!(no_db.lines < all.lines);
        assert!(no_db.files < all.files);
    }
}
