//! The ESP2 benchmark (§3.2.1): Table 3 and figures 4–8.
//!
//! ESP ("Effective System Performance", Wong et al., SC2000) measures the
//! time a batch system needs to run a fixed 230-job mix whose per-job
//! runtimes are fixed targets, so the result depends only on scheduling
//! quality and per-job launch overhead. The paper runs the *throughput*
//! variant (all jobs submitted at t = 0) on 34 processors and reports
//! Elapsed Time + Efficiency for SGE, Torque, Maui+Torque, OAR and OAR(2)
//! (Table 3), plus the utilization profiles (figs. 4–8).
//!
//! Job classes: the ESP mix (fraction of system, count); runtimes are
//! rescaled so the jobmix work equals the paper's 443,340 CPU·s on 34
//! processors (lower bound 13,039 s — Table 3's "Jobmix work" row), per
//! the substitution note in DESIGN.md.

use crate::sched::baselines::{MauiLike, SgeLike, TorqueLike};
use crate::sched::policies::{FifoConservative, QueuePolicy, SjfConservative};
use crate::sim::{simulate, SimConfig, SimJob, SimResult};
use crate::types::{NodeId, Time};

/// One ESP job class: (name, fraction of system, count, base target
/// runtime in seconds — ESP-2 values).
pub const ESP_CLASSES: &[(&str, f64, u32, Time)] = &[
    ("A", 0.03125, 75, 267),
    ("B", 0.06250, 9, 322),
    ("C", 0.50000, 3, 534),
    ("D", 0.25000, 3, 616),
    ("E", 0.50000, 3, 315),
    ("F", 0.06250, 9, 1846),
    ("G", 0.12500, 6, 1334),
    ("H", 0.15625, 6, 1067),
    ("I", 0.03125, 24, 1432),
    ("J", 0.06250, 24, 725),
    ("K", 0.09375, 15, 487),
    ("L", 0.12500, 36, 366),
    ("M", 0.25000, 15, 187),
    ("Z", 1.00000, 2, 100),
];

/// The paper's jobmix work on the Xeon platform (CPU·seconds, Table 3).
pub const PAPER_JOBMIX_WORK: i64 = 443_340;

/// Processors of the Xeon platform exploited by the schedulers.
pub const XEON_PROCS: u32 = 34;

/// Paper's Table 3 numbers, for side-by-side reporting.
pub const PAPER_TABLE3: &[(&str, i64, f64)] = &[
    ("SGE", 14_164, 0.9206),
    ("TORQUE", 14_818, 0.8800),
    ("TORQUE+MAUI", 15_115, 0.8627),
    ("OAR", 15_264, 0.8543),
    ("OAR(2)", 14_037, 0.9289),
];

/// Generate the ESP2 throughput workload for a machine of `procs`
/// processors: 230 jobs, all submitted at t = 0 in a *seeded-random
/// order* (ESP randomizes submission order — this is what puts the
/// full-configuration Z jobs mid-queue and makes FIFO schedulers pay a
/// drain, the effect behind Table 3's spread). Runtimes are rescaled so
/// the total work matches [`PAPER_JOBMIX_WORK`] when `procs == 34`.
pub fn esp_workload(procs: u32) -> Vec<SimJob> {
    esp_workload_seeded(procs, 2005)
}

/// Seeded variant (benches sweep seeds for robustness).
pub fn esp_workload_seeded(procs: u32, seed: u64) -> Vec<SimJob> {
    let mut raw: Vec<(u32, Time)> = Vec::new();
    for (_, frac, count, base) in ESP_CLASSES {
        let p = ((frac * procs as f64).round() as u32).clamp(1, procs);
        for _ in 0..*count {
            raw.push((p, *base));
        }
    }
    let mut rng = crate::util::Rng::new(seed);
    rng.shuffle(&mut raw);
    let raw_work: i64 = raw.iter().map(|(p, t)| *p as i64 * t).sum();
    let target_work = PAPER_JOBMIX_WORK as f64 * (procs as f64 / XEON_PROCS as f64);
    let scale = target_work / raw_work as f64;
    raw.iter()
        .enumerate()
        .map(|(i, (p, t))| {
            let runtime = ((*t as f64 * scale).round() as Time).max(1);
            SimJob {
                id: i as u64 + 1,
                nb_nodes: *p,
                weight: 1,
                runtime,
                max_time: runtime, // ESP gives schedulers accurate estimates
                submit: 0,
            }
        })
        .collect()
}

/// One Table 3 row produced by our reproduction.
#[derive(Debug, Clone)]
pub struct EspRow {
    pub system: &'static str,
    pub elapsed: Time,
    pub efficiency: f64,
    /// Famine indicator: the maximum job wait time (§3.2.1 discussion).
    pub max_wait: Time,
    pub result: SimResult,
}

/// The five schedulers of Table 3, in the paper's column order.
pub fn table3_schedulers() -> Vec<(&'static str, Box<dyn QueuePolicy>)> {
    vec![
        ("SGE", Box::new(SgeLike)),
        ("TORQUE", Box::new(TorqueLike)),
        ("TORQUE+MAUI", Box::new(MauiLike)),
        ("OAR", Box::new(FifoConservative)),
        ("OAR(2)", Box::new(SjfConservative)),
    ]
}

/// Run the full ESP benchmark: one row per scheduler (Table 3), each row
/// carrying the utilization trace for its figure (figs. 4–8).
pub fn run_esp(procs: u32, launch_overhead: Time) -> Vec<EspRow> {
    let nodes: Vec<(NodeId, u32)> = (1..=procs).map(|i| (i, 1)).collect();
    let jobs = esp_workload(procs);
    table3_schedulers()
        .into_iter()
        .map(|(system, policy)| {
            let result = simulate(
                policy.as_ref(),
                &nodes,
                &jobs,
                SimConfig { launch_overhead },
            );
            EspRow {
                system,
                elapsed: result.elapsed(),
                efficiency: result.efficiency(),
                max_wait: result.max_wait_time(),
                result,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_matches_esp_shape() {
        let jobs = esp_workload(XEON_PROCS);
        assert_eq!(jobs.len(), 230, "ESP is a 230-job mix");
        // two full-configuration Z jobs
        assert_eq!(
            jobs.iter().filter(|j| j.nb_nodes == XEON_PROCS).count(),
            2,
            "exactly the two Z jobs use the full machine"
        );
        // total work calibrated to the paper's number (±1% rounding)
        let work: i64 = jobs.iter().map(|j| j.runtime * j.total_procs() as i64).sum();
        let err = (work - PAPER_JOBMIX_WORK).abs() as f64 / PAPER_JOBMIX_WORK as f64;
        assert!(err < 0.01, "work {work} vs {PAPER_JOBMIX_WORK}");
    }

    #[test]
    fn lower_bound_matches_paper() {
        let jobs = esp_workload(XEON_PROCS);
        let work: i64 = jobs.iter().map(|j| j.runtime * j.total_procs() as i64).sum();
        let lower_bound = work / XEON_PROCS as i64;
        // paper: 443340 / 34 = 13039s
        assert!((lower_bound - 13_039).abs() < 140, "lower bound {lower_bound}");
    }

    #[test]
    fn all_schedulers_complete_the_mix() {
        // small machine to keep the test fast in debug builds
        let rows = run_esp(8, 0);
        assert_eq!(rows.len(), 5);
        for row in &rows {
            assert_eq!(row.result.records.len(), 230, "{}", row.system);
            assert!(row.efficiency > 0.5, "{}: {}", row.system, row.efficiency);
            assert!(row.efficiency <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn famine_ordering_holds() {
        // The paper's qualitative claim: greedy small-first packers (SGE)
        // starve big jobs; OAR's conservative FIFO does not. Compare the
        // mean wait of jobs needing >= half the machine.
        let rows = run_esp(8, 0);
        let big_wait = |name: &str| {
            let r = rows.iter().find(|r| r.system == name).unwrap();
            let waits: Vec<i64> = r
                .result
                .records
                .iter()
                .filter(|rec| rec.procs >= 4)
                .map(|rec| rec.wait_time())
                .collect();
            waits.iter().sum::<i64>() as f64 / waits.len() as f64
        };
        assert!(
            big_wait("OAR") < big_wait("SGE"),
            "OAR {} vs SGE {}",
            big_wait("OAR"),
            big_wait("SGE")
        );
    }
}
