//! Submission-burst benchmarks (§3.2.2): figures 9 and 10.
//!
//! Fig. 9 — "average response time of small jobs depending on the total
//! number of simultaneous submissions" on the Xeon platform (17 nodes):
//! B identical 1-node `date` jobs are submitted at once through the full
//! live stack (admission → database → central module → meta-scheduler →
//! launcher → virtual nodes); the scheduler has no decisions to make, so
//! the measurement isolates system overhead — exactly the paper's test.
//!
//! Fig. 10 — "average response time of parallel jobs depending on the
//! number of nodes required" on the Icluster platform (119 nodes), for
//! the four OAR launcher settings (rsh/ssh × check/no-check) and the
//! Torque-like baseline.
//!
//! Both run against the real server with modeled launcher latencies; the
//! `time_scale` knob compresses wall-clock without changing the measured
//! *modeled* response times' structure.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::{Protocol, VirtualCluster};
use crate::launcher::LauncherConfig;
use crate::server::{Server, ServerConfig};
use crate::types::JobSpec;
use crate::util::Summary;
use crate::Result;

/// One fig. 9 measurement point.
#[derive(Debug, Clone)]
pub struct BurstPoint {
    pub burst: usize,
    /// Response-time summary over the burst's jobs, milliseconds.
    pub response_ms: Summary,
    /// Jobs that ended in error (must be 0 for a stable system).
    pub errors: usize,
    /// Wall time to drain the burst, ms.
    pub drain_ms: u64,
    /// SQL-equivalent queries issued while processing the burst.
    pub queries: u64,
}

/// Fig. 9: submit `burst` 1-node `date` jobs at once; measure response
/// times through the live stack.
pub fn burst_response(
    cluster: Arc<VirtualCluster>,
    burst: usize,
    config: ServerConfig,
) -> Result<BurstPoint> {
    let server = Server::new(cluster, config);
    server.with_db(|db| db.reset_stats());
    let t0 = std::time::Instant::now();
    let mut ids = Vec::with_capacity(burst);
    for i in 0..burst {
        let id = server
            .submit(&JobSpec::batch(&format!("u{}", i % 16), "date", 1, 300))
            ?
            .map_err(|e| anyhow::anyhow!("admission rejected: {e}"))?;
        ids.push(id);
    }
    let ok = server.wait_all_terminal(Duration::from_secs(600));
    anyhow::ensure!(ok, "burst {burst} did not drain");
    let drain_ms = t0.elapsed().as_millis() as u64;

    let mut responses = Vec::with_capacity(burst);
    let mut errors = 0;
    let queries = server.with_db(|db| db.stats().total());
    for id in ids {
        let job = server.with_db(|db| db.job(id))?;
        match job.response_time() {
            Some(r) if job.state == crate::types::JobState::Terminated => {
                responses.push(r as f64)
            }
            _ => errors += 1,
        }
    }
    Ok(BurstPoint {
        burst,
        response_ms: Summary::of(&responses),
        errors,
        drain_ms,
        queries,
    })
}

/// Fig. 9 sweep over burst sizes on the Xeon platform.
pub fn fig9_sweep(bursts: &[usize], time_scale: f64) -> Result<Vec<BurstPoint>> {
    bursts
        .iter()
        .map(|b| {
            let cluster = Arc::new(VirtualCluster::xeon());
            let mut cfg = ServerConfig::fast(time_scale);
            cfg.launcher.protocol = Protocol::Ssh;
            cfg.launcher.check_before_launch = false;
            burst_response(cluster, *b, cfg)
        })
        .collect()
}

/// One fig. 10 series: launcher setting name + (nb_nodes → mean response
/// ms, modeled).
#[derive(Debug, Clone)]
pub struct ParallelSeries {
    pub setting: String,
    pub points: Vec<(u32, f64)>,
}

/// Fig. 10: response time of one parallel job of `nb_nodes` nodes on the
/// Icluster platform, per launcher setting. The response is dominated by
/// the deployment cost model, so we measure through the server once per
/// (setting, size).
pub fn fig10_sweep(sizes: &[u32], time_scale: f64) -> Result<Vec<ParallelSeries>> {
    let settings: Vec<(String, Protocol, bool)> = vec![
        ("oar-rsh".into(), Protocol::Rsh, false),
        ("oar-rsh+check".into(), Protocol::Rsh, true),
        ("oar-ssh".into(), Protocol::Ssh, false),
        ("oar-ssh+check".into(), Protocol::Ssh, true),
    ];
    let mut out = Vec::new();
    for (name, protocol, check) in settings {
        let mut points = Vec::new();
        for &size in sizes {
            let cluster = Arc::new(VirtualCluster::icluster());
            let mut cfg = ServerConfig::fast(time_scale);
            cfg.launcher = LauncherConfig {
                protocol,
                check_before_launch: check,
                connect_timeout: Duration::from_secs(5),
                time_scale,
            };
            let server = Server::new(cluster, cfg);
            let id = server
                .submit(&JobSpec::batch("u", "date", size, 300))?
                .map_err(|e| anyhow::anyhow!("rejected: {e}"))?;
            anyhow::ensure!(
                server.wait_all_terminal(Duration::from_secs(120)),
                "{name}/{size} did not finish"
            );
            let job = server.with_db(|db| db.job(id))?;
            // measured end-to-end response (submission -> termination);
            // run at time_scale=1.0 for real-scale numbers.
            let resp = job.response_time().unwrap_or(0) as f64;
            points.push((size, resp));
        }
        out.push(ParallelSeries {
            setting: name,
            points,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_burst_drains_cleanly() {
        let cluster = Arc::new(VirtualCluster::tiny(4, 1));
        let mut cfg = ServerConfig::fast(0.0);
        cfg.sched.dense_matching = false;
        let p = burst_response(cluster, 25, cfg).unwrap();
        assert_eq!(p.errors, 0);
        assert_eq!(p.response_ms.n, 25);
        assert!(p.queries > 0, "query counting must be active");
    }

    #[test]
    fn fig10_orderings_hold() {
        // real scale so the protocol latency dominates measurement noise
        let series = fig10_sweep(&[1, 8], 1.0).unwrap();
        let get = |name: &str| {
            series
                .iter()
                .find(|s| s.setting == name)
                .unwrap()
                .points
                .iter()
                .map(|(_, v)| *v)
                .sum::<f64>()
        };
        assert!(get("oar-ssh") > get("oar-rsh"), "ssh slower than rsh");
        assert!(
            get("oar-ssh+check") > get("oar-ssh"),
            "check adds a round-trip"
        );
    }
}
