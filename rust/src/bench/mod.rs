//! Benchmark harnesses regenerating every table and figure of the
//! paper's evaluation (§3):
//!
//! * [`esp`] — the ESP2 benchmark: Table 3 and figs. 4–8.
//! * [`burst`] — submission bursts: figs. 9 and 10.
//! * [`complexity`] — software complexity: Table 1.
//! * [`features`] — functionality matrix: Table 2.
//! * [`report`] — ASCII rendering + CSV output shared by the harnesses.

pub mod burst;
pub mod complexity;
pub mod esp;
pub mod features;
pub mod report;
