//! Shared reporting: ASCII utilization plots (the figures), aligned
//! tables, and CSV files under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use crate::sim::SimResult;
use crate::types::Time;

/// Render a fig. 4–8 style utilization profile: plain line = busy
/// processors over time, markers = job starts.
pub fn utilization_ascii(result: &SimResult, width: usize, height: usize) -> String {
    let elapsed = result.elapsed().max(1);
    let cap = result.total_procs.max(1) as usize;
    let mut grid = vec![vec![' '; width]; height];

    // busy-processor staircase
    let mut level = 0u32;
    let mut trace = result.utilization.clone();
    trace.sort_by_key(|(t, _)| *t);
    let col_of = |t: Time| ((t as f64 / elapsed as f64) * (width - 1) as f64) as usize;
    let row_of = |busy: u32| {
        let frac = busy as f64 / cap as f64;
        height - 1 - ((frac * (height - 1) as f64).round() as usize).min(height - 1)
    };
    let mut prev_col = 0usize;
    for (t, busy) in trace {
        let col = col_of(t).min(width - 1);
        let row = row_of(level);
        for c in prev_col..=col {
            grid[row][c] = '-';
        }
        level = busy;
        prev_col = col;
    }
    let row = row_of(level);
    for c in prev_col..width {
        grid[row][c] = '-';
    }

    // start markers (dashed vertical lines with height = procs requested)
    for (t, procs) in &result.starts {
        let col = col_of(*t).min(width - 1);
        let top = row_of(*procs);
        for r in grid.iter_mut().skip(top) {
            if r[col] == ' ' {
                r[col] = ':';
            }
        }
    }

    let mut out = String::new();
    let _ = writeln!(out, "{cap} procs ┐");
    for row in grid {
        let line: String = row.into_iter().collect();
        let _ = writeln!(out, "       │{line}");
    }
    let _ = writeln!(out, "     0 └{}", "─".repeat(width));
    let _ = writeln!(out, "        t=0{}t={elapsed}s", " ".repeat(width.saturating_sub(12)));
    out
}

/// Aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    let _ = writeln!(out, "{}", fmt_row(&head, &widths));
    let _ = writeln!(out, "{}", "─".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        let _ = writeln!(out, "{}", fmt_row(row, &widths));
    }
    out
}

/// Write rows as CSV under `results/` (created if needed).
pub fn write_csv(path: &Path, headers: &[&str], rows: &[Vec<String>]) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut text = headers.join(",");
    text.push('\n');
    for row in rows {
        text.push_str(&row.join(","));
        text.push('\n');
    }
    std::fs::write(path, text)?;
    Ok(())
}

/// Simple ASCII x/y plot for figs. 9–10 (log-ish labeling left to caller).
pub fn xy_ascii(series: &[(&str, &[(f64, f64)])], width: usize, height: usize) -> String {
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().copied())
        .collect();
    if all.is_empty() {
        return String::new();
    }
    let (xmin, xmax) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), (x, _)| {
            (lo.min(*x), hi.max(*x))
        });
    let (ymin, ymax) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), (_, y)| {
            (lo.min(*y), hi.max(*y))
        });
    let xspan = (xmax - xmin).max(1e-9);
    let yspan = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (x, y) in pts.iter() {
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = height - 1 - (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "{ymax:>10.1} ┐");
    for row in grid {
        let line: String = row.into_iter().collect();
        let _ = writeln!(out, "           │{line}");
    }
    let _ = writeln!(out, "{ymin:>10.1} └{}", "─".repeat(width));
    let _ = writeln!(out, "            {xmin:<10.0}{}{xmax:>10.0}", " ".repeat(width.saturating_sub(20)));
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "            {} {}", marks[si % marks.len()], name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimConfig, SimJob};
    use crate::sched::policies::FifoConservative;

    #[test]
    fn utilization_plot_renders() {
        let jobs = [
            SimJob { id: 1, nb_nodes: 2, weight: 1, runtime: 50, max_time: 50, submit: 0 },
            SimJob { id: 2, nb_nodes: 1, weight: 1, runtime: 100, max_time: 100, submit: 0 },
        ];
        let r = simulate(&FifoConservative, &[(1, 1), (2, 1), (3, 1)], &jobs, SimConfig::default());
        let plot = utilization_ascii(&r, 40, 8);
        assert!(plot.contains('-'));
        assert!(plot.contains(':'));
        assert!(plot.lines().count() >= 8);
    }

    #[test]
    fn table_aligns() {
        let t = table(
            &["name", "x"],
            &[vec!["a".into(), "1".into()], vec!["longer".into(), "22".into()]],
        );
        assert!(t.contains("longer"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn csv_writes() {
        let dir = std::env::temp_dir().join("oar_csv_test");
        let path = dir.join("x.csv");
        write_csv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn xy_plot_renders_series() {
        let s1 = [(1.0, 2.0), (2.0, 4.0)];
        let s2 = [(1.0, 1.0), (2.0, 8.0)];
        let plot = xy_ascii(&[("a", &s1), ("b", &s2)], 30, 10);
        assert!(plot.contains('*'));
        assert!(plot.contains('o'));
    }
}
