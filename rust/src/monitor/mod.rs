//! The monitoring module (§2.4): node-state surveillance through the
//! launcher's reachability sweep, recorded in the database (so the
//! scheduler simply stops matching `Suspected` nodes) and in the event
//! log.

use std::sync::Arc;

use crate::db::Db;
use crate::launcher::Launcher;
use crate::types::{NodeState, Time};
use crate::Result;

/// Outcome of one monitoring round.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MonitorReport {
    /// Nodes newly marked `Suspected`.
    pub suspected: Vec<crate::types::NodeId>,
    /// Nodes that recovered to `Alive`.
    pub recovered: Vec<crate::types::NodeId>,
}

/// Run one monitoring round: ping every node, reconcile database state.
/// The fleet listing takes a shared read guard (status queries proceed
/// concurrently); only the state transitions take the write lock.
pub fn monitor_round(
    db: &std::sync::RwLock<Db>,
    launcher: &Launcher,
    now: Time,
) -> Result<MonitorReport> {
    // Declared before either guard: both drop before the span records.
    let _round = crate::obs::Span::enter("monitor.round", &crate::obs::metrics::MONITOR_ROUND_US);
    let nodes = db.read().unwrap().all_nodes();
    let ids: Vec<_> = nodes.iter().map(|n| n.id).collect();
    let states = launcher.ping_all(&ids);

    let mut report = MonitorReport::default();
    let mut db = db.write().unwrap();
    for (node, reachable) in states {
        let current = nodes.iter().find(|n| n.id == node).unwrap();
        match (current.state, reachable) {
            (NodeState::Alive, false) => {
                db.set_node_state(node, NodeState::Suspected)?;
                db.log_event(now, "NODE_SUSPECTED", None, &current.hostname);
                report.suspected.push(node);
            }
            (NodeState::Suspected, true) => {
                db.set_node_state(node, NodeState::Alive)?;
                db.log_event(now, "NODE_RECOVERED", None, &current.hostname);
                report.recovered.push(node);
            }
            // Absent nodes are administratively off: never auto-changed.
            _ => {}
        }
    }
    Ok(report)
}

/// Helper used by `oarnodes`: summarize fleet state. Read-only, answered
/// from the `fleet` materialized view — same rows, same order as the old
/// `all_nodes` decode, without touching the nodes table.
pub fn fleet_summary(db: &Db) -> Vec<(String, String, u32)> {
    db.fleet_view()
}

pub use std::sync::RwLock as DbLock;

/// Convenience alias used by the server: the reader-writer core. Status
/// queries share read guards; mutation batches serialize on the write
/// half.
pub type SharedDb = Arc<std::sync::RwLock<Db>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::VirtualCluster;
    use crate::launcher::LauncherConfig;

    #[test]
    fn suspect_and_recover_cycle() {
        let cluster = Arc::new(VirtualCluster::tiny(3, 1));
        let mut db = Db::new();
        cluster.register(&mut db);
        let db = std::sync::RwLock::new(db);
        let launcher = Launcher::new(
            cluster.clone(),
            LauncherConfig {
                time_scale: 0.0,
                ..Default::default()
            },
        );

        cluster.inject_failure(2);
        let r = monitor_round(&db, &launcher, 100).unwrap();
        assert_eq!(r.suspected, vec![2]);
        assert!(r.recovered.is_empty());
        {
            let d = db.read().unwrap();
            assert_eq!(d.alive_nodes().len(), 2);
            assert_eq!(d.events().iter().filter(|e| e.kind == "NODE_SUSPECTED").count(), 1);
        }

        // repeated round: no duplicate transitions
        let r = monitor_round(&db, &launcher, 101).unwrap();
        assert_eq!(r, MonitorReport::default());

        cluster.repair(2);
        let r = monitor_round(&db, &launcher, 102).unwrap();
        assert_eq!(r.recovered, vec![2]);
        assert_eq!(db.read().unwrap().alive_nodes().len(), 3);
    }
}
