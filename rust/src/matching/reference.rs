//! Pure-Rust reference implementation of `schedule_step` — semantically
//! identical to `python/compile/kernels/ref.py` (and therefore to the
//! Pallas kernels, which are pytest-pinned to that oracle). Used when the
//! AOT artifact is absent and as the comparison side of the
//! runtime-vs-reference integration tests.

use crate::Result;

use super::shapes::{F, J, N, P, T};
use super::{ScheduleStep, StepInput, StepOutput};

/// CPU reference engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct ReferenceStep;

impl ScheduleStep for ReferenceStep {
    fn run(&mut self, input: &StepInput) -> Result<StepOutput> {
        Ok(run_reference(input))
    }

    fn engine_name(&self) -> &'static str {
        "rust_reference"
    }
}

/// The dense computation, mirroring `schedule_step_ref`:
/// `elig = all_p(lo <= prop <= hi)`, `freecount = elig @ node_free`,
/// `earliest = first window of dur slots with freecount >= req`,
/// `scores = feats @ weights`.
pub fn run_reference(input: &StepInput) -> StepOutput {
    let mut elig = vec![0.0f32; J * N];
    for j in 0..J {
        let lo = &input.job_lo[j * P..(j + 1) * P];
        let hi = &input.job_hi[j * P..(j + 1) * P];
        for n in 0..N {
            let props = &input.node_props[n * P..(n + 1) * P];
            let ok = (0..P).all(|p| lo[p] <= props[p] && props[p] <= hi[p]);
            elig[j * N + n] = if ok { 1.0 } else { 0.0 };
        }
    }

    // freecount = elig @ node_free ([J,N] @ [N,T])
    let mut freecount = vec![0.0f32; J * T];
    for j in 0..J {
        for n in 0..N {
            let e = elig[j * N + n];
            if e == 0.0 {
                continue;
            }
            let row = &input.node_free[n * T..(n + 1) * T];
            let out = &mut freecount[j * T..(j + 1) * T];
            for t in 0..T {
                out[t] += e * row[t];
            }
        }
    }

    // earliest: consecutive-run scan
    let mut earliest = vec![-1.0f32; J];
    for j in 0..J {
        let req = input.req[j];
        let dur = input.dur[j];
        let fc = &freecount[j * T..(j + 1) * T];
        let mut run = 0.0f32;
        for (t, &v) in fc.iter().enumerate() {
            run = if v >= req { run + 1.0 } else { 0.0 };
            if run >= dur && earliest[j] < 0.0 {
                earliest[j] = t as f32 - dur + 1.0;
            }
        }
    }

    // scores = feats @ weights
    let mut scores = vec![0.0f32; J];
    for j in 0..J {
        let feats = &input.job_feats[j * F..(j + 1) * F];
        scores[j] = feats
            .iter()
            .zip(&input.weights)
            .map(|(a, b)| a * b)
            .sum();
    }

    StepOutput {
        elig,
        freecount,
        earliest,
        scores,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::shapes::{HI_UNBOUNDED, LO_UNBOUNDED};

    #[test]
    fn unconstrained_job_matches_all_nodes() {
        let mut input = StepInput::zeros();
        for p in 0..P {
            input.job_lo[p] = LO_UNBOUNDED;
            input.job_hi[p] = HI_UNBOUNDED;
        }
        let out = run_reference(&input);
        assert_eq!(out.elig[..N].iter().sum::<f32>(), N as f32);
    }

    #[test]
    fn freecount_sums_eligible_nodes_only() {
        let mut input = StepInput::zeros();
        // job 0: eligible iff prop0 >= 1; nodes 0..4 have prop0 = 1.
        input.job_lo[0] = 1.0;
        input.job_hi[0] = HI_UNBOUNDED;
        for p in 1..P {
            input.job_lo[p] = LO_UNBOUNDED;
            input.job_hi[p] = HI_UNBOUNDED;
        }
        for n in 0..4 {
            input.node_props[n * P] = 1.0;
            for t in 0..T {
                input.node_free[n * T + t] = 2.0;
            }
        }
        // node 5 has capacity but prop0 = 0 -> ineligible.
        for t in 0..T {
            input.node_free[5 * T + t] = 2.0;
        }
        let out = run_reference(&input);
        assert_eq!(out.elig[..N].iter().sum::<f32>(), 4.0);
        assert_eq!(out.freecount[0], 8.0);
    }

    #[test]
    fn earliest_and_scores() {
        let mut input = StepInput::zeros();
        for p in 0..P {
            input.job_lo[p] = LO_UNBOUNDED;
            input.job_hi[p] = HI_UNBOUNDED;
        }
        // node 0 free from slot 10 onward with 4 procs
        for t in 10..T {
            input.node_free[t] = 4.0;
        }
        input.req[0] = 4.0;
        input.dur[0] = 5.0;
        input.job_feats[0] = 2.0;
        input.weights[0] = 3.0;
        let out = run_reference(&input);
        assert_eq!(out.earliest[0], 10.0);
        assert_eq!(out.scores[0], 6.0);
        // job 1 (padding, req=0) starts at 0
        assert_eq!(out.earliest[1], 0.0);
    }

    #[test]
    fn infeasible_job_gets_minus_one() {
        let mut input = StepInput::zeros();
        input.req[0] = 1.0; // no node has capacity and none matched
        let out = run_reference(&input);
        assert_eq!(out.earliest[0], -1.0);
    }
}
