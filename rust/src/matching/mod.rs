//! The resource-matching compute path.
//!
//! OAR matches resources by evaluating each job's `properties` SQL
//! expression against the nodes table (§2). That is the scheduler's hot
//! loop once queues get deep, so this reproduction also expresses it as a
//! dense batched computation (the L1/L2 JAX+Pallas artifact): jobs'
//! constraints are compiled to per-property intervals, nodes to property
//! vectors, and one `schedule_step` evaluation yields the full J×N
//! eligibility matrix, per-job free-count timelines, earliest feasible
//! start estimates and priority scores.
//!
//! Three interchangeable engines:
//! * [`SqlMatcher`] — row-at-a-time expression evaluation (the paper's
//!   semantics, ground truth).
//! * [`ReferenceStep`] — pure-Rust dense path, bit-identical to the Pallas
//!   kernels' semantics (`python/compile/kernels/ref.py`).
//! * `runtime::HloStep` — the AOT artifact through PJRT (the production
//!   hot path).
//!
//! Jobs whose expressions are not interval-expressible (disjunctions,
//! LIKE, NOT...) are flagged by the [`encode::Encoder`] and fall back to
//! the SQL path; the dense engines only ever see interval-expressible
//! constraints, so dense and SQL semantics agree wherever both apply.

pub mod encode;
pub mod reference;
pub mod shapes;

pub use encode::{EncodedBatch, Encoder};
pub use reference::ReferenceStep;
pub use shapes::{F, J, N, P, T};

use crate::Result;

/// Flat row-major tensors for one `schedule_step` evaluation, padded to
/// the AOT shapes ([`shapes`]).
#[derive(Debug, Clone)]
pub struct StepInput {
    pub job_lo: Vec<f32>,     // [J, P]
    pub job_hi: Vec<f32>,     // [J, P]
    pub node_props: Vec<f32>, // [N, P]
    pub node_free: Vec<f32>,  // [N, T]
    pub req: Vec<f32>,        // [J]
    pub dur: Vec<f32>,        // [J]
    pub job_feats: Vec<f32>,  // [J, F]
    pub weights: Vec<f32>,    // [F]
}

impl StepInput {
    /// Zero-filled input at the canonical shapes.
    pub fn zeros() -> StepInput {
        StepInput {
            job_lo: vec![0.0; J * P],
            job_hi: vec![0.0; J * P],
            node_props: vec![0.0; N * P],
            node_free: vec![0.0; N * T],
            req: vec![0.0; J],
            dur: vec![1.0; J],
            job_feats: vec![0.0; J * F],
            weights: vec![0.0; F],
        }
    }
}

/// Outputs of one `schedule_step` evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutput {
    pub elig: Vec<f32>,      // [J, N]
    pub freecount: Vec<f32>, // [J, T]
    pub earliest: Vec<f32>,  // [J]
    pub scores: Vec<f32>,    // [J]
}

/// An engine that evaluates one scheduling round's dense compute.
pub trait ScheduleStep {
    fn run(&mut self, input: &StepInput) -> Result<StepOutput>;

    /// Human-readable engine name (benchmark labels).
    fn engine_name(&self) -> &'static str;
}

/// Row-at-a-time SQL matching: ground truth for eligibility.
pub struct SqlMatcher;

impl SqlMatcher {
    /// Eligible alive nodes for one properties expression.
    pub fn eligible_nodes(
        properties: &str,
        nodes: &[crate::types::Node],
    ) -> Result<Vec<crate::types::NodeId>> {
        let expr = crate::db::Expr::parse(properties)
            .map_err(|e| anyhow::anyhow!("bad properties expression: {e}"))?;
        Ok(nodes
            .iter()
            .filter(|n| n.is_alive() && expr.matches(&n.property_row()))
            .map(|n| n.id)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Value;
    use crate::types::Node;

    #[test]
    fn sql_matcher_filters_alive_and_expr() {
        let mut n1 = Node::new(1, "n1", 2).with_prop("mem", Value::Int(256));
        let n2 = Node::new(2, "n2", 2).with_prop("mem", Value::Int(2048));
        n1.state = crate::types::NodeState::Suspected;
        let nodes = vec![n1, n2];
        let got = SqlMatcher::eligible_nodes("mem >= 128", &nodes).unwrap();
        assert_eq!(got, vec![2], "suspected node excluded");
    }
}
