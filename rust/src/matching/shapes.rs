//! Canonical AOT compile shapes — keep in sync with
//! `python/compile/model.py` and `artifacts/manifest.json`.

/// Jobs per scheduling-round chunk (larger queues are chunked).
pub const J: usize = 64;
/// Max nodes (covers the Xeon 17-node and Icluster 119-node testbeds).
pub const N: usize = 128;
/// Matchable numeric properties per node.
pub const P: usize = 8;
/// Gantt horizon slots fed to the feasibility scan.
pub const T: usize = 96;
/// Priority features per job.
pub const F: usize = 6;

/// Default wall-seconds per horizon slot (96 slots × 300 s = 8 h window).
pub const DEFAULT_SLOT_SECS: i64 = 300;

/// "Unbounded" sentinels for interval constraints. Finite (not ±inf) so
/// no NaN can leak out of downstream arithmetic.
pub const LO_UNBOUNDED: f32 = -1.0e30;
pub const HI_UNBOUNDED: f32 = 1.0e30;

/// Property value assigned to *padding* node columns: strictly below
/// [`LO_UNBOUNDED`], so even an unconstrained job rejects padding nodes.
pub const PAD_PROP: f32 = -2.0e30;

#[cfg(test)]
mod tests {
    /// Guard: shapes must match the python manifest when artifacts exist.
    #[test]
    fn matches_manifest_when_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            return; // artifacts not built yet; covered by integration tests
        };
        let v = crate::util::Json::parse(&text).unwrap();
        let get = |k: &str| v.get(k).and_then(crate::util::Json::as_i64).unwrap() as usize;
        assert_eq!(get("J"), super::J);
        assert_eq!(get("N"), super::N);
        assert_eq!(get("P"), super::P);
        assert_eq!(get("T"), super::T);
        assert_eq!(get("F"), super::F);
    }
}
