//! Encoding: jobs' SQL `properties` expressions + node property rows +
//! Gantt free capacity → the padded tensors of [`super::StepInput`].
//!
//! The encoder builds a *property vocabulary* (up to [`P`] columns) from
//! the fleet's property keys. Numeric properties map directly; text
//! properties get a per-column dictionary (value → integer code) so that
//! text *equality* constraints (`switch = 'sw1'`) become degenerate
//! intervals `[code, code]` and stay kernel-expressible.
//!
//! Jobs whose expression uses anything beyond conjunctive interval logic
//! (OR, NOT, LIKE, IN, cross-column arithmetic...) are reported in
//! [`EncodedBatch::fallback`] and resolved by the SQL path instead — so
//! dense and SQL semantics agree wherever the dense path is used.
//!
//! Semantics note: nodes *missing* a vocabulary property encode as
//! [`LO_UNBOUNDED`], which satisfies only unconstrained columns; clusters
//! in this repo define every vocabulary property on every node, keeping
//! the dense path exactly equal to SQL matching (asserted by proptests).

use std::collections::BTreeMap;

use crate::db::{Expr, Value};
use crate::types::{JobId, Node, NodeId, Time};

use super::shapes::{F, HI_UNBOUNDED, J, LO_UNBOUNDED, N, P, PAD_PROP, T};
use super::StepInput;

/// What the encoder needs to know about one waiting job.
#[derive(Debug, Clone)]
pub struct JobToMatch {
    pub id: JobId,
    pub properties: String,
    /// Total processors required (drives the feasibility scan's `req`).
    pub total_procs: u32,
    /// Duration in seconds (rounded *up* to horizon slots).
    pub duration: Time,
    /// Feature vector inputs for the priority score.
    pub wait_time: Time,
    pub queue_priority: i32,
    pub best_effort: bool,
}

/// Result of encoding one batch of ≤ J jobs against ≤ N nodes.
#[derive(Debug)]
pub struct EncodedBatch {
    pub input: StepInput,
    /// Job ids in tensor row order (row i ↔ jobs[i]).
    pub job_rows: Vec<JobId>,
    /// Node ids in tensor column order.
    pub node_cols: Vec<NodeId>,
    /// Jobs that must be matched by the SQL path instead.
    pub fallback: Vec<JobId>,
}

/// Stateful encoder: owns the vocabulary and text dictionaries so codes
/// stay stable across rounds.
#[derive(Debug, Default)]
pub struct Encoder {
    /// Property column names, at most P.
    vocab: Vec<String>,
    /// Per-column text dictionaries (column name → value → code).
    dicts: BTreeMap<String, BTreeMap<String, i64>>,
}

impl Encoder {
    /// Build the vocabulary from the fleet. Property keys are sorted for
    /// determinism; numeric-valued keys come first so they win the ≤ P cut.
    pub fn from_nodes(nodes: &[Node]) -> Encoder {
        let mut numeric = Vec::new();
        let mut textual = Vec::new();
        for node in nodes {
            for (k, v) in &node.properties {
                match v {
                    Value::Int(_) | Value::Real(_) | Value::Bool(_) => {
                        if !numeric.contains(k) {
                            numeric.push(k.clone());
                        }
                    }
                    Value::Text(_) => {
                        if !textual.contains(k) {
                            textual.push(k.clone());
                        }
                    }
                    Value::Null => {}
                }
            }
        }
        numeric.sort();
        textual.sort();
        let mut vocab: Vec<String> = numeric;
        vocab.extend(textual.iter().cloned());
        vocab.truncate(P);

        let mut dicts: BTreeMap<String, BTreeMap<String, i64>> = BTreeMap::new();
        for col in &vocab {
            let mut values: Vec<String> = nodes
                .iter()
                .filter_map(|n| n.properties.get(col))
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect();
            if values.is_empty() {
                continue;
            }
            values.sort();
            values.dedup();
            let dict = values
                .into_iter()
                .enumerate()
                .map(|(i, v)| (v, i as i64))
                .collect();
            dicts.insert(col.clone(), dict);
        }
        Encoder { vocab, dicts }
    }

    pub fn vocab(&self) -> &[String] {
        &self.vocab
    }

    /// Compile one properties expression into per-vocab-column intervals.
    /// `None` = not dense-expressible (SQL fallback).
    pub fn intervals_for(&self, properties: &str) -> Option<Vec<(f32, f32)>> {
        let expr = Expr::parse(properties).ok()?;
        let rewritten = self.rewrite_text_eq(&expr)?;
        let map = rewritten.to_intervals()?;
        // Every constrained column must be inside the vocabulary, else the
        // dense path would silently ignore the constraint.
        for col in map.keys() {
            if !self.vocab.contains(col) {
                return None;
            }
        }
        let mut out = vec![(LO_UNBOUNDED, HI_UNBOUNDED); self.vocab.len()];
        for (i, col) in self.vocab.iter().enumerate() {
            if let Some((lo, hi)) = map.get(col) {
                out[i] = (lo_to_f32(*lo), hi_to_f32(*hi));
            }
        }
        Some(out)
    }

    /// Rewrite `text_col = 'value'` into `text_col = <code>` using the
    /// dictionaries; unknown values become an empty interval (lo > hi),
    /// correctly matching no node. Any other use of a text column defeats
    /// the rewrite (→ None → SQL fallback).
    fn rewrite_text_eq(&self, expr: &Expr) -> Option<Expr> {
        use crate::db::Expr::*;
        Some(match expr {
            And(a, b) => And(
                Box::new(self.rewrite_text_eq(a)?),
                Box::new(self.rewrite_text_eq(b)?),
            ),
            Cmp(op, a, b) => {
                let (col, lit, flipped) = match (&**a, &**b) {
                    (Column(c), Literal(v)) => (c, v, false),
                    (Literal(v), Column(c)) => (c, v, true),
                    _ => return None,
                };
                match lit {
                    Value::Text(s) => {
                        if *op != crate::db::CmpOp::Eq {
                            return None; // only equality on text columns
                        }
                        let code = self
                            .dicts
                            .get(col)
                            .and_then(|d| d.get(s))
                            .copied();
                        match code {
                            Some(code) => Cmp(
                                *op,
                                Box::new(Column(col.clone())),
                                Box::new(Literal(Value::Int(code))),
                            ),
                            // unknown text value: impossible constraint
                            None => And(
                                Box::new(Cmp(
                                    crate::db::CmpOp::Ge,
                                    Box::new(Column(col.clone())),
                                    Box::new(Literal(Value::Real(1.0))),
                                )),
                                Box::new(Cmp(
                                    crate::db::CmpOp::Le,
                                    Box::new(Column(col.clone())),
                                    Box::new(Literal(Value::Real(0.0))),
                                )),
                            ),
                        }
                    }
                    _ => {
                        let _ = flipped;
                        expr.clone()
                    }
                }
            }
            Between(..) | Literal(..) => expr.clone(),
            _ => return None,
        })
    }

    /// Node property row in vocabulary order (text → code, missing → very
    /// small).
    pub fn node_row(&self, node: &Node) -> Vec<f32> {
        self.vocab
            .iter()
            .map(|col| match node.properties.get(col) {
                Some(Value::Int(i)) => *i as f32,
                Some(Value::Real(r)) => *r as f32,
                Some(Value::Bool(b)) => *b as i64 as f32,
                Some(Value::Text(s)) => self
                    .dicts
                    .get(col)
                    .and_then(|d| d.get(s))
                    .map(|c| *c as f32)
                    .unwrap_or(LO_UNBOUNDED),
                _ => LO_UNBOUNDED,
            })
            .collect()
    }

    /// Encode a batch (≤ J jobs, ≤ N nodes) with the given per-node free
    /// capacity matrix `node_free[n][t]` (from [`crate::sched::Gantt::
    /// free_matrix`]) and slot length.
    pub fn encode(
        &self,
        jobs: &[JobToMatch],
        nodes: &[Node],
        node_free: &[Vec<f32>],
        slot_secs: Time,
        weights: [f32; F],
    ) -> EncodedBatch {
        assert!(jobs.len() <= J, "chunk jobs to J");
        assert!(nodes.len() <= N, "cluster exceeds N");
        let mut input = StepInput::zeros();
        input.weights = weights.to_vec();

        let mut job_rows = Vec::with_capacity(jobs.len());
        let mut fallback = Vec::new();
        for (row, job) in jobs.iter().enumerate() {
            job_rows.push(job.id);
            match self.intervals_for(&job.properties) {
                Some(iv) => {
                    for (p, (lo, hi)) in iv.iter().enumerate() {
                        input.job_lo[row * P + p] = *lo;
                        input.job_hi[row * P + p] = *hi;
                    }
                    for p in iv.len()..P {
                        input.job_lo[row * P + p] = LO_UNBOUNDED;
                        input.job_hi[row * P + p] = HI_UNBOUNDED;
                    }
                }
                None => {
                    // SQL fallback: make the dense row match nothing so a
                    // stale read cannot over-promise.
                    for p in 0..P {
                        input.job_lo[row * P + p] = 1.0;
                        input.job_hi[row * P + p] = 0.0;
                    }
                    fallback.push(job.id);
                }
            }
            input.req[row] = job.total_procs as f32;
            input.dur[row] = ((job.duration + slot_secs - 1) / slot_secs).max(1) as f32;
            let feats = [
                (job.wait_time as f32 / 3600.0).min(100.0),
                job.queue_priority as f32,
                job.total_procs as f32,
                (job.duration as f32 / 3600.0).min(1000.0),
                job.best_effort as i32 as f32,
                1.0,
            ];
            input.job_feats[row * F..(row + 1) * F].copy_from_slice(&feats);
        }
        // Padding rows (req = 0) match nothing and scan to 0 harmlessly.
        for row in jobs.len()..J {
            for p in 0..P {
                input.job_lo[row * P + p] = 1.0;
                input.job_hi[row * P + p] = 0.0;
            }
        }

        let mut node_cols = Vec::with_capacity(nodes.len());
        for (col, node) in nodes.iter().enumerate() {
            node_cols.push(node.id);
            let row = self.node_row(node);
            for (p, v) in row.iter().enumerate() {
                input.node_props[col * P + p] = *v;
            }
            for p in row.len()..P {
                input.node_props[col * P + p] = LO_UNBOUNDED;
            }
            let free = &node_free[col];
            for t in 0..T.min(free.len()) {
                input.node_free[col * T + t] = free[t];
            }
        }
        // Padding nodes must match NO job, not even unconstrained ones:
        // their property value sits below every admissible lower bound.
        for col in nodes.len()..N {
            for p in 0..P {
                input.node_props[col * P + p] = PAD_PROP;
            }
        }

        EncodedBatch {
            input,
            job_rows,
            node_cols,
            fallback,
        }
    }
}

/// Convert an f64 lower bound to f32, rounding *up* (inward) so the f32
/// interval never admits a node the f64 interval excludes.
fn lo_to_f32(v: f64) -> f32 {
    if v.is_infinite() {
        return LO_UNBOUNDED;
    }
    let f = v as f32;
    if (f as f64) < v {
        f.next_up()
    } else {
        f
    }
}

/// Convert an f64 upper bound to f32, rounding *down* (inward).
fn hi_to_f32(v: f64) -> f32 {
    if v.is_infinite() {
        return HI_UNBOUNDED;
    }
    let f = v as f32;
    if (f as f64) > v {
        f.next_down()
    } else {
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::reference::run_reference;
    use crate::matching::SqlMatcher;

    fn fleet() -> Vec<Node> {
        (0..6)
            .map(|i| {
                Node::new(i as NodeId + 1, &format!("n{i}"), 2)
                    .with_prop("mem", Value::Int(256 * (i as i64 + 1)))
                    .with_prop("cpu_mhz", Value::Int(2400))
                    .with_prop("switch", Value::Text(if i < 3 { "sw1" } else { "sw2" }.into()))
            })
            .collect()
    }

    fn jtm(id: JobId, properties: &str) -> JobToMatch {
        JobToMatch {
            id,
            properties: properties.into(),
            total_procs: 1,
            duration: 300,
            wait_time: 0,
            queue_priority: 1,
            best_effort: false,
        }
    }

    #[test]
    fn vocabulary_is_deterministic_and_numeric_first() {
        let enc = Encoder::from_nodes(&fleet());
        // numeric: cpu_mhz, mem, nb_procs; text: switch
        assert_eq!(enc.vocab(), &["cpu_mhz", "mem", "nb_procs", "switch"]);
    }

    #[test]
    fn numeric_intervals() {
        let enc = Encoder::from_nodes(&fleet());
        let iv = enc.intervals_for("mem >= 512 AND cpu_mhz >= 2000").unwrap();
        assert_eq!(iv[1].0, 512.0); // mem column
        assert!(iv[0].0 >= 2000.0); // cpu_mhz column
    }

    #[test]
    fn text_equality_becomes_code_interval() {
        let enc = Encoder::from_nodes(&fleet());
        let iv = enc.intervals_for("switch = 'sw2'").unwrap();
        let sw = iv[3];
        assert_eq!(sw.0, sw.1, "degenerate interval");
        // unknown switch value matches nothing
        let iv = enc.intervals_for("switch = 'sw9'").unwrap();
        assert!(iv[3].0 > iv[3].1, "empty interval");
    }

    #[test]
    fn disjunction_falls_back() {
        let enc = Encoder::from_nodes(&fleet());
        assert!(enc.intervals_for("mem >= 512 OR cpu_mhz >= 9000").is_none());
        assert!(enc.intervals_for("hostname LIKE 'n%'").is_none());
        assert!(enc.intervals_for("switch != 'sw1'").is_none());
    }

    #[test]
    fn unknown_column_falls_back() {
        let enc = Encoder::from_nodes(&fleet());
        assert!(enc.intervals_for("gpus >= 2").is_none());
    }

    #[test]
    fn dense_path_agrees_with_sql_path() {
        let nodes = fleet();
        let enc = Encoder::from_nodes(&nodes);
        let free = vec![vec![2.0f32; T]; nodes.len()];
        let exprs = [
            "",
            "mem >= 512",
            "mem >= 512 AND switch = 'sw1'",
            "switch = 'sw2'",
            "mem BETWEEN 256 AND 768",
            "cpu_mhz > 2400",
        ];
        let jobs: Vec<JobToMatch> = exprs
            .iter()
            .enumerate()
            .map(|(i, e)| jtm(i as JobId + 1, e))
            .collect();
        let batch = enc.encode(&jobs, &nodes, &free, 300, [0.0; F]);
        assert!(batch.fallback.is_empty());
        let out = run_reference(&batch.input);
        for (row, job) in jobs.iter().enumerate() {
            let want = SqlMatcher::eligible_nodes(&job.properties, &nodes).unwrap();
            let got: Vec<NodeId> = batch
                .node_cols
                .iter()
                .enumerate()
                .filter(|(col, _)| out.elig[row * N + col] == 1.0)
                .map(|(_, id)| *id)
                .collect();
            assert_eq!(got, want, "expr {:?}", job.properties);
        }
    }

    #[test]
    fn padding_rows_and_cols_are_inert() {
        let nodes = fleet();
        let enc = Encoder::from_nodes(&nodes);
        let free = vec![vec![2.0f32; T]; nodes.len()];
        let batch = enc.encode(&[jtm(1, "")], &nodes, &free, 300, [0.0; F]);
        let out = run_reference(&batch.input);
        // row 0 matches the 6 real nodes and none of the padding columns
        assert_eq!(out.elig[..N].iter().sum::<f32>(), 6.0);
        // padding rows match nothing
        for row in 1..J {
            assert_eq!(out.elig[row * N..(row + 1) * N].iter().sum::<f32>(), 0.0);
        }
    }

    #[test]
    fn duration_rounds_up_to_slots() {
        let nodes = fleet();
        let enc = Encoder::from_nodes(&nodes);
        let free = vec![vec![2.0f32; T]; nodes.len()];
        let mut job = jtm(1, "");
        job.duration = 301; // just over one slot
        let batch = enc.encode(&[job], &nodes, &free, 300, [0.0; F]);
        assert_eq!(batch.input.dur[0], 2.0);
    }
}
