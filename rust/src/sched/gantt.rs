//! The Gantt diagram: "an internal representation of the available
//! ressources similar to a Gantt diagram" (§2.3). The meta-scheduler
//! initializes it with the currently-executing jobs and the accepted
//! reservations, then each queue's scheduler carves its jobs into the
//! remaining holes.
//!
//! The representation is per-*node* processor-count timelines: each node
//! holds a list of `(start, stop, procs)` allocations; a job asking for
//! `nb_nodes` nodes × `weight` procs/node fits at time `t` on a node iff
//! the node's free processor count stays ≥ `weight` over `[t, t + dur)`.

use std::collections::BTreeMap;


use crate::resources::Hierarchy;
use crate::types::{JobId, NodeId, Time};

/// One placed allocation (a rectangle of the Gantt).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub job: JobId,
    pub start: Time,
    pub stop: Time,
    pub procs: u32,
}

/// Per-node timeline.
#[derive(Debug, Clone)]
struct NodeTimeline {
    nb_procs: u32,
    /// Allocations, kept sorted by start time.
    allocs: Vec<Allocation>,
}

impl NodeTimeline {
    /// Free processors at instant `t`. Allocations are kept sorted by
    /// start, so only the prefix with `start <= t` can be active.
    fn free_at(&self, t: Time) -> i64 {
        let hi = self.allocs.partition_point(|a| a.start <= t);
        let busy: i64 = self.allocs[..hi]
            .iter()
            .filter(|a| t < a.stop)
            .map(|a| a.procs as i64)
            .sum();
        self.nb_procs as i64 - busy
    }

    /// Minimum free processors over the window `[t, t + dur)`: one sweep
    /// over the allocations overlapping the window (perf: this is the
    /// innermost loop of every placement — see EXPERIMENTS.md §Perf).
    /// Events live in a stack buffer for the common few-overlaps case
    /// (§Perf iteration 3: a heap allocation here doubled the greedy
    /// baselines' whole-run cost).
    fn min_free_over(&self, t: Time, dur: Time) -> i64 {
        const STACK: usize = 32;
        let end = t.saturating_add(dur);
        let hi = self.allocs.partition_point(|a| a.start < end);
        let mut busy_at_t: i64 = 0;
        let mut buf = [(0 as Time, 0i64); STACK];
        let mut n = 0;
        let mut spill: Vec<(Time, i64)> = Vec::new();
        let mut push = |ev: (Time, i64), buf: &mut [(Time, i64); STACK], n: &mut usize, spill: &mut Vec<(Time, i64)>| {
            if *n < STACK {
                buf[*n] = ev;
                *n += 1;
            } else {
                spill.push(ev);
            }
        };
        for a in &self.allocs[..hi] {
            if a.stop <= t {
                continue;
            }
            if a.start <= t {
                busy_at_t += a.procs as i64;
            } else {
                push((a.start, a.procs as i64), &mut buf, &mut n, &mut spill);
            }
            if a.stop < end {
                push((a.stop, -(a.procs as i64)), &mut buf, &mut n, &mut spill);
            }
        }
        if n == 0 && spill.is_empty() {
            return self.nb_procs as i64 - busy_at_t;
        }
        // Sort by (time, delta): releases (-) apply before acquisitions (+)
        // at the same instant, matching the exclusive-stop semantics.
        let events: &mut [(Time, i64)] = if spill.is_empty() {
            &mut buf[..n]
        } else {
            spill.extend_from_slice(&buf[..n]);
            &mut spill[..]
        };
        events.sort_unstable();
        let mut busy = busy_at_t;
        let mut max_busy = busy;
        for (_, d) in events.iter() {
            busy += *d;
            max_busy = max_busy.max(busy);
        }
        self.nb_procs as i64 - max_busy
    }

    /// Time ranges `[lo, hi]` (inclusive, `hi` may be `FAR_FUTURE`) from
    /// which a `(weight, dur)` job could *start* on this node: every hole
    /// of the busy profile with `free >= weight` lasting at least `dur`,
    /// shrunk by `dur` at the tail. Single sweep over the allocations.
    fn feasible_starts(&self, weight: u32, dur: Time, not_before: Time) -> Vec<(Time, Time)> {
        if weight > self.nb_procs {
            return Vec::new();
        }
        // busy-profile events
        let mut events: Vec<(Time, i64)> = Vec::with_capacity(self.allocs.len() * 2);
        for a in &self.allocs {
            events.push((a.start, a.procs as i64));
            events.push((a.stop, -(a.procs as i64)));
        }
        events.sort_unstable();
        let cap = self.nb_procs as i64;
        let need = weight as i64;
        let mut out = Vec::new();
        let mut busy = 0i64;
        let mut ok_since: Option<Time> = Some(Time::MIN / 4); // free before first event
        let mut close = |since: Option<Time>, until: Time, out: &mut Vec<(Time, Time)>| {
            if let Some(lo) = since {
                // hole is [lo, until): valid starts are [lo, until - dur]
                let hi = until - dur;
                let lo = lo.max(not_before);
                if hi >= lo {
                    out.push((lo, hi));
                }
            }
        };
        let mut i = 0;
        while i < events.len() {
            let t = events[i].0;
            while i < events.len() && events[i].0 == t {
                busy += events[i].1;
                i += 1;
            }
            let ok = cap - busy >= need;
            match (ok_since, ok) {
                (Some(_), true) | (None, false) => {}
                (Some(_), false) => {
                    close(ok_since, t, &mut out);
                    ok_since = None;
                }
                (None, true) => ok_since = Some(t),
            }
        }
        // trailing hole extends forever
        if let Some(lo) = ok_since {
            out.push((lo.max(not_before), FAR_FUTURE));
        }
        out
    }
}

/// Sentinel for "unbounded" interval ends (far enough that `+ dur` cannot
/// overflow).
pub const FAR_FUTURE: Time = Time::MAX / 4;

/// The whole diagram.
#[derive(Debug, Clone)]
pub struct Gantt {
    nodes: BTreeMap<NodeId, NodeTimeline>,
    /// Placement tree for hierarchical (`/switch=…`) requests; `None`
    /// keeps every policy on the flat per-node path.
    hierarchy: Option<Hierarchy>,
    /// Moldable placements recorded while policies carve the diagram:
    /// `(job, nb_nodes, weight)` of the alternative that won. The
    /// meta-scheduler drains these and persists the chosen shape for
    /// jobs that actually start.
    reshapes: Vec<(JobId, u32, u32)>,
}

impl Gantt {
    /// Build an empty diagram over `(node, nb_procs)` resources.
    pub fn new(nodes: &[(NodeId, u32)]) -> Gantt {
        Gantt {
            nodes: nodes
                .iter()
                .map(|(id, procs)| {
                    (
                        *id,
                        NodeTimeline {
                            nb_procs: *procs,
                            allocs: Vec::new(),
                        },
                    )
                })
                .collect(),
            hierarchy: None,
            reshapes: Vec::new(),
        }
    }

    /// Attach the placement tree used by hierarchical requests.
    pub fn set_hierarchy(&mut self, hierarchy: Hierarchy) {
        self.hierarchy = Some(hierarchy);
    }

    pub fn hierarchy(&self) -> Option<&Hierarchy> {
        self.hierarchy.as_ref()
    }

    /// Record that `job` was placed with a shape other than its stored
    /// `nbNodes × weight` (a moldable alternative won).
    pub fn note_reshape(&mut self, job: JobId, nb_nodes: u32, weight: u32) {
        self.reshapes.push((job, nb_nodes, weight));
    }

    /// Drain the recorded moldable placements.
    pub fn take_reshapes(&mut self) -> Vec<(JobId, u32, u32)> {
        std::mem::take(&mut self.reshapes)
    }

    /// Inclusive time ranges from which a `(weight, dur)` job could start
    /// on `node` — the per-node timeline scan behind [`Gantt::find_earliest`],
    /// exposed so the tree matcher
    /// ([`crate::resources::find_earliest_tree`]) can stack per-level
    /// interval counting on top of it.
    pub fn feasible_starts(
        &self,
        node: NodeId,
        weight: u32,
        dur: Time,
        not_before: Time,
    ) -> Vec<(Time, Time)> {
        self.nodes
            .get(&node)
            .map(|tl| tl.feasible_starts(weight, dur, not_before))
            .unwrap_or_default()
    }

    pub fn node_ids(&self) -> Vec<NodeId> {
        self.nodes.keys().copied().collect()
    }

    pub fn total_procs(&self) -> u32 {
        self.nodes.values().map(|n| n.nb_procs).sum()
    }

    /// Occupy `procs` processors of `node` over `[start, stop)`.
    /// Fails (returning `false`, placing nothing) on oversubscription or an
    /// unknown node — the invariant the proptests lean on.
    pub fn occupy(&mut self, job: JobId, node: NodeId, procs: u32, start: Time, stop: Time) -> bool {
        if stop <= start {
            return false;
        }
        let Some(tl) = self.nodes.get(&node) else {
            return false;
        };
        if tl.min_free_over(start, stop - start) < procs as i64 {
            return false;
        }
        let tl = self.nodes.get_mut(&node).unwrap();
        let alloc = Allocation { job, start, stop, procs };
        let pos = tl.allocs.partition_point(|a| a.start <= start);
        tl.allocs.insert(pos, alloc);
        true
    }

    /// Remove every allocation of `job` (used when a best-effort job is
    /// cancelled or a running job terminates early).
    pub fn release_job(&mut self, job: JobId) {
        for tl in self.nodes.values_mut() {
            tl.allocs.retain(|a| a.job != job);
        }
    }

    /// Free processors of `node` at `t` (0 for unknown nodes).
    pub fn free_at(&self, node: NodeId, t: Time) -> i64 {
        self.nodes.get(&node).map(|tl| tl.free_at(t)).unwrap_or(0)
    }

    /// Nodes from `eligible` that can host `weight` procs over
    /// `[t, t + dur)`, in id order.
    pub fn available_nodes_at(
        &self,
        eligible: &[NodeId],
        weight: u32,
        t: Time,
        dur: Time,
    ) -> Vec<NodeId> {
        eligible
            .iter()
            .filter(|id| {
                self.nodes
                    .get(id)
                    .map(|tl| tl.min_free_over(t, dur) >= weight as i64)
                    .unwrap_or(false)
            })
            .copied()
            .collect()
    }

    /// Earliest `t >= not_before` at which `nb_nodes` of the eligible nodes
    /// each have `weight` free procs for `dur` seconds; returns the chosen
    /// nodes. This is the per-job hole-finding walk the L1 kernel
    /// accelerates in bulk (the kernel prunes+orders, this gives the exact
    /// placement).
    ///
    /// Implementation (EXPERIMENTS.md §Perf iteration 2): each node's
    /// feasible-start ranges are computed with one sweep of its own
    /// allocation list, then one global event sweep finds the earliest
    /// instant covered by ≥ `nb_nodes` ranges — O(Σ_n A_n log A_n) per
    /// placement instead of the previous per-candidate × per-node rescan.
    pub fn find_earliest(
        &self,
        eligible: &[NodeId],
        nb_nodes: u32,
        weight: u32,
        dur: Time,
        not_before: Time,
    ) -> Option<(Time, Vec<NodeId>)> {
        if nb_nodes == 0 || dur <= 0 {
            return Some((not_before, Vec::new()));
        }
        // Coverage events over feasible-start ranges [lo, hi] (inclusive).
        let mut events: Vec<(Time, i64)> = Vec::new();
        for id in eligible {
            if let Some(tl) = self.nodes.get(id) {
                for (lo, hi) in tl.feasible_starts(weight, dur, not_before) {
                    events.push((lo, 1));
                    events.push((hi.saturating_add(1), -1));
                }
            }
        }
        events.sort_unstable();
        let mut covered = 0i64;
        let mut i = 0;
        let mut t = None;
        while i < events.len() {
            let at = events[i].0;
            while i < events.len() && events[i].0 == at {
                covered += events[i].1;
                i += 1;
            }
            if covered >= nb_nodes as i64 {
                t = Some(at);
                break;
            }
        }
        let t = t?;
        // Materialize the node choice at t (id order, as before).
        let avail = self.available_nodes_at(eligible, weight, t, dur);
        debug_assert!(avail.len() >= nb_nodes as usize);
        Some((t, avail[..nb_nodes as usize].to_vec()))
    }

    /// Busy processors summed over all nodes at instant `t` — the
    /// utilization curve of figs. 4–8.
    pub fn busy_procs_at(&self, t: Time) -> u32 {
        self.nodes
            .values()
            .map(|tl| tl.nb_procs as i64 - tl.free_at(t))
            .sum::<i64>() as u32
    }

    /// All allocations (for rendering and invariant checks).
    pub fn allocations(&self) -> Vec<(NodeId, Allocation)> {
        let mut out = Vec::new();
        for (id, tl) in &self.nodes {
            for a in &tl.allocs {
                out.push((*id, a.clone()));
            }
        }
        out
    }

    /// Latest allocation stop time (makespan of the planned schedule).
    pub fn makespan(&self) -> Time {
        self.nodes
            .values()
            .flat_map(|tl| tl.allocs.iter().map(|a| a.stop))
            .max()
            .unwrap_or(0)
    }

    /// Discretize free capacity into the `node_free[N, T]` tensor consumed
    /// by the L1 kernel path: entry `(n, t)` is the node's *minimum* free
    /// proc count over slot `t` (conservative: a slot partially busy counts
    /// as its worst instant, so the kernel never over-promises).
    pub fn free_matrix(
        &self,
        nodes: &[NodeId],
        origin: Time,
        slot_secs: Time,
        slots: usize,
    ) -> Vec<Vec<f32>> {
        nodes
            .iter()
            .map(|id| {
                (0..slots)
                    .map(|s| {
                        let t = origin + s as Time * slot_secs;
                        self.nodes
                            .get(id)
                            .map(|tl| tl.min_free_over(t, slot_secs).max(0) as f32)
                            .unwrap_or(0.0)
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gantt2() -> Gantt {
        // two nodes, 2 procs each
        Gantt::new(&[(1, 2), (2, 2)])
    }

    #[test]
    fn occupy_and_free() {
        let mut g = gantt2();
        assert!(g.occupy(10, 1, 2, 0, 100));
        assert_eq!(g.free_at(1, 50), 0);
        assert_eq!(g.free_at(1, 100), 2, "stop is exclusive");
        assert_eq!(g.free_at(2, 50), 2);
        assert_eq!(g.busy_procs_at(50), 2);
    }

    #[test]
    fn oversubscription_is_rejected() {
        let mut g = gantt2();
        assert!(g.occupy(1, 1, 2, 0, 10));
        assert!(!g.occupy(2, 1, 1, 5, 15), "node 1 is full over [0,10)");
        assert!(g.occupy(2, 1, 1, 10, 15), "free after the first stops");
    }

    #[test]
    fn zero_length_and_unknown_node_rejected() {
        let mut g = gantt2();
        assert!(!g.occupy(1, 1, 1, 10, 10));
        assert!(!g.occupy(1, 99, 1, 0, 10));
    }

    #[test]
    fn find_earliest_immediately() {
        let g = gantt2();
        let (t, nodes) = g.find_earliest(&[1, 2], 2, 1, 60, 0).unwrap();
        assert_eq!(t, 0);
        assert_eq!(nodes, vec![1, 2]);
    }

    #[test]
    fn find_earliest_after_release() {
        let mut g = gantt2();
        g.occupy(1, 1, 2, 0, 100);
        g.occupy(1, 2, 2, 0, 40);
        // wants both nodes fully: must wait for node 1 at t=100
        let (t, nodes) = g.find_earliest(&[1, 2], 2, 2, 10, 0).unwrap();
        assert_eq!(t, 100);
        assert_eq!(nodes.len(), 2);
        // a 1-node job fits at t=40 on node 2
        let (t, nodes) = g.find_earliest(&[1, 2], 1, 2, 10, 0).unwrap();
        assert_eq!(t, 40);
        assert_eq!(nodes, vec![2]);
    }

    #[test]
    fn find_earliest_respects_window_interior() {
        let mut g = gantt2();
        // node 1 busy over [50, 60): a 100s job starting at 0 cannot use it
        g.occupy(1, 1, 2, 50, 60);
        let (t, nodes) = g.find_earliest(&[1], 1, 1, 100, 0).unwrap();
        assert_eq!(t, 60);
        assert_eq!(nodes, vec![1]);
    }

    #[test]
    fn find_earliest_none_for_impossible() {
        let g = gantt2();
        assert!(g.find_earliest(&[1, 2], 3, 1, 10, 0).is_none());
        assert!(g.find_earliest(&[1], 1, 3, 10, 0).is_none());
    }

    #[test]
    fn weight_aware_packing() {
        let mut g = gantt2();
        // one proc of node 1 taken forever
        g.occupy(7, 1, 1, 0, 1_000_000);
        // weight-2 job cannot use node 1
        let (t, nodes) = g.find_earliest(&[1, 2], 1, 2, 10, 0).unwrap();
        assert_eq!((t, nodes), (0, vec![2]));
        // weight-1 job still can
        let (_, nodes) = g.find_earliest(&[1, 2], 2, 1, 10, 0).unwrap();
        assert_eq!(nodes, vec![1, 2]);
    }

    #[test]
    fn release_job_frees_everything() {
        let mut g = gantt2();
        g.occupy(5, 1, 2, 0, 100);
        g.occupy(5, 2, 2, 0, 100);
        assert_eq!(g.busy_procs_at(10), 4);
        g.release_job(5);
        assert_eq!(g.busy_procs_at(10), 0);
        assert!(g.allocations().is_empty());
    }

    #[test]
    fn makespan() {
        let mut g = gantt2();
        assert_eq!(g.makespan(), 0);
        g.occupy(1, 1, 1, 0, 30);
        g.occupy(2, 2, 1, 10, 70);
        assert_eq!(g.makespan(), 70);
    }

    /// Build a timeline directly (allocations sorted by start, as the
    /// `occupy` path maintains) to probe `min_free_over` boundaries.
    fn timeline(nb_procs: u32, allocs: &[(Time, Time, u32)]) -> NodeTimeline {
        let mut sorted = allocs.to_vec();
        sorted.sort_by_key(|a| a.0);
        NodeTimeline {
            nb_procs,
            allocs: sorted
                .into_iter()
                .enumerate()
                .map(|(i, (start, stop, procs))| Allocation {
                    job: i as JobId,
                    start,
                    stop,
                    procs,
                })
                .collect(),
        }
    }

    #[test]
    fn min_free_allocation_meeting_exactly_at_t() {
        // Alloc ends exactly at t: stop is exclusive, so [t, t+dur) is free.
        let tl = timeline(2, &[(0, 10, 2)]);
        assert_eq!(tl.min_free_over(10, 10), 2);
        // One instant earlier it still overlaps.
        assert_eq!(tl.min_free_over(9, 10), 0);
    }

    #[test]
    fn min_free_allocation_meeting_exactly_at_t_plus_dur() {
        // Alloc starts exactly at t+dur: outside the window [t, t+dur).
        let tl = timeline(2, &[(10, 20, 2)]);
        assert_eq!(tl.min_free_over(0, 10), 2);
        // Window extended by one instant now overlaps.
        assert_eq!(tl.min_free_over(0, 11), 0);
        // Alloc exactly covering the window.
        let tl = timeline(2, &[(5, 15, 1)]);
        assert_eq!(tl.min_free_over(5, 10), 1);
        assert_eq!(tl.min_free_over(14, 1), 1);
        assert_eq!(tl.min_free_over(15, 1), 2);
    }

    #[test]
    fn min_free_release_before_acquire_at_same_instant() {
        // A releases at 50 exactly where B acquires: exclusive-stop
        // semantics mean they never coexist — the min must be 0, not -2.
        let tl = timeline(2, &[(0, 50, 2), (50, 100, 2)]);
        assert_eq!(tl.min_free_over(0, 100), 0);
        assert_eq!(tl.min_free_over(49, 2), 0);
        // Back-to-back with capacity to spare on one side.
        let tl = timeline(2, &[(0, 50, 1), (50, 100, 2)]);
        assert_eq!(tl.min_free_over(0, 100), 0);
        assert_eq!(tl.min_free_over(0, 50), 1);
        // The same boundary through the public occupy path: a job slotting
        // exactly between two full allocations must be accepted.
        let mut g = Gantt::new(&[(1, 2)]);
        assert!(g.occupy(1, 1, 2, 0, 50));
        assert!(g.occupy(2, 1, 2, 50, 100));
        assert!(g.occupy(3, 1, 2, 100, 150), "handoff instants stay free");
        assert!(!g.occupy(4, 1, 1, 49, 51), "straddling the handoff fails");
    }

    #[test]
    fn min_free_spill_path_beyond_stack_buffer() {
        // 40 staggered allocations inside the window contribute 80 events,
        // far past the 32-slot stack buffer: the spill path must agree
        // with the exact peak (40 concurrent over [40, 150)).
        let allocs: Vec<(Time, Time, u32)> = (1..=40).map(|i| (i as Time, 150, 1)).collect();
        let tl = timeline(64, &allocs);
        assert_eq!(tl.min_free_over(0, 200), 64 - 40);
        // A narrower window sees only the prefix (19 starts, no stops) and
        // stays on the stack path — same accounting, different code path.
        assert_eq!(tl.min_free_over(0, 20), 64 - 19);
        // Occupy-level check across the spill path.
        let mut g = Gantt::new(&[(1, 64)]);
        for (i, (start, stop, procs)) in allocs.iter().enumerate() {
            assert!(g.occupy(100 + i as JobId, 1, *procs, *start, *stop));
        }
        assert!(g.occupy(9000, 1, 24, 0, 200));
        assert!(!g.occupy(9001, 1, 1, 0, 200), "exactly full at the peak");
    }

    #[test]
    fn public_feasible_starts_mirrors_the_timeline_scan() {
        let mut g = gantt2();
        g.occupy(1, 1, 2, 10, 20);
        // Full node over [10, 20): a 5s single-proc job can start in
        // [0, 5] (finishing by 10) or any time from 20 on.
        let r = g.feasible_starts(1, 1, 5, 0);
        assert_eq!(r, vec![(0, 5), (20, FAR_FUTURE)]);
        // Unknown nodes have no feasible starts.
        assert!(g.feasible_starts(99, 1, 5, 0).is_empty());
    }

    #[test]
    fn reshape_channel_drains_once() {
        let mut g = gantt2();
        assert!(g.take_reshapes().is_empty());
        g.note_reshape(7, 2, 4);
        assert_eq!(g.take_reshapes(), vec![(7, 2, 4)]);
        assert!(g.take_reshapes().is_empty(), "drained");
    }

    #[test]
    fn free_matrix_is_conservative() {
        let mut g = gantt2();
        g.occupy(1, 1, 2, 5, 15); // busy inside slot 0 (0..10) and slot 1
        let m = g.free_matrix(&[1, 2], 0, 10, 3);
        assert_eq!(m[0], vec![0.0, 0.0, 2.0], "partially-busy slots count 0");
        assert_eq!(m[1], vec![2.0, 2.0, 2.0]);
    }
}
