//! Per-queue scheduling policies (§2.3).
//!
//! "The whole algorithm schedules each queue in turn by decreasing
//! priority using it associated scheduler" — these are the associated
//! schedulers. Each policy receives the queue's waiting jobs and the
//! shared Gantt diagram (already loaded with running jobs, reservations
//! and higher-priority placements) and carves its jobs into the holes.
//!
//! * [`FifoConservative`] — OAR's default: submission order, *conservative*
//!   backfilling ("we do not allow jobs to be delayed within a given
//!   queue", §3.2.1): every job gets a placement, so a later job can only
//!   use holes that do not delay any earlier one.
//! * [`SjfConservative`] — the OAR(2) variant of Table 3: same machinery,
//!   queue order changed to increasing number of required resources.
//! * [`BestEffortPolicy`] — §3.3: place only on resources idle *now*; the
//!   meta-scheduler cancels these jobs when their resources are reclaimed.

use crate::resources::{find_earliest_tree, Shape};
use crate::types::{JobId, NodeId, Time};

use super::gantt::Gantt;

/// One moldable alternative of a hierarchical request, ready for
/// placement: the tree shape plus its own eligible set when the
/// alternative carried a `{properties}` filter (`None` = use the
/// job-level eligibility).
#[derive(Debug, Clone)]
pub struct AltShape {
    pub shape: Shape,
    pub eligible: Option<Vec<NodeId>>,
}

/// The scheduler-facing view of a waiting job: fig. 2's scheduling fields
/// plus the pre-computed eligible node set (resource matching result).
#[derive(Debug, Clone)]
pub struct PolicyJob {
    pub id: JobId,
    pub nb_nodes: u32,
    /// Processors per node (fig. 2 `weight`).
    pub weight: u32,
    /// Planned duration = `maxTime`.
    pub duration: Time,
    pub submission_time: Time,
    /// Nodes matching the job's `properties` expression.
    pub eligible: Vec<NodeId>,
    pub best_effort: bool,
    /// Priority score from the matching kernel (higher first); tie-broken
    /// by submission order. 0 when scoring is disabled.
    pub score: f32,
    /// Moldable/hierarchical alternatives (the `-l … -l …` request);
    /// empty for flat jobs, which use `nb_nodes × weight` directly.
    /// `nb_nodes`/`weight` always mirror the first alternative, so the
    /// SJF ordering key stays meaningful for moldable jobs too.
    pub alts: Vec<AltShape>,
}

impl PolicyJob {
    /// Saturating for the same reason as [`crate::types::Job::total_procs`]:
    /// an adversarial row must not wrap into a tiny SJF ordering key.
    pub fn total_procs(&self) -> u32 {
        self.nb_nodes.saturating_mul(self.weight)
    }
}

/// A start decision: job → nodes it starts on *now*.
pub type Start = (JobId, Vec<NodeId>);

/// A per-queue scheduler.
pub trait QueuePolicy {
    fn name(&self) -> &'static str;

    /// Place `jobs` into `gantt` (future placements included); return the
    /// jobs that start at `now` with their nodes.
    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start>;
}

// ------------------------------------------------------------------------

/// Place one job at its earliest feasible time and record the allocation.
/// Returns the start time and nodes when a placement exists.
///
/// Flat jobs (no alternatives) take the plain `find_earliest` walk. A
/// moldable job evaluates *every* alternative's earliest start — the
/// tree matcher for switch-constrained shapes, the flat walk otherwise —
/// and the earliest one wins (ties go to the first alternative, the
/// paper's "first feasible" rule at equal times). When the winning shape
/// differs from the job row's `nbNodes × weight`, the reshape is
/// recorded on the Gantt for the meta-scheduler to persist.
fn place_conservative(
    now: Time,
    job: &PolicyJob,
    gantt: &mut Gantt,
) -> Option<(Time, Vec<NodeId>)> {
    if job.alts.is_empty() {
        let (t, nodes) =
            gantt.find_earliest(&job.eligible, job.nb_nodes, job.weight, job.duration, now)?;
        for n in &nodes {
            let ok = gantt.occupy(job.id, *n, job.weight, t, t + job.duration);
            debug_assert!(ok, "find_earliest must return occupiable nodes");
        }
        return Some((t, nodes));
    }

    let mut best: Option<(Time, Vec<NodeId>, usize)> = None;
    for (i, alt) in job.alts.iter().enumerate() {
        let eligible = alt.eligible.as_deref().unwrap_or(&job.eligible);
        let candidate = match alt.shape.switches {
            Some(_) => gantt.hierarchy().and_then(|tree| {
                find_earliest_tree(tree, eligible, &alt.shape, |node, procs| {
                    gantt.feasible_starts(node, procs, job.duration, now)
                })
            }),
            None => alt.shape.total_hosts().and_then(|hosts| {
                gantt.find_earliest(eligible, hosts, alt.shape.cores, job.duration, now)
            }),
        };
        if let Some((t, nodes)) = candidate {
            if best.as_ref().is_none_or(|(bt, _, _)| t < *bt) {
                best = Some((t, nodes, i));
            }
        }
    }
    let (t, nodes, idx) = best?;
    let shape = job.alts[idx].shape;
    let weight = shape.weight();
    for n in &nodes {
        let ok = gantt.occupy(job.id, *n, weight, t, t + job.duration);
        debug_assert!(ok, "matcher must return occupiable nodes");
    }
    if nodes.len() as u32 != job.nb_nodes || weight != job.weight {
        gantt.note_reshape(job.id, nodes.len() as u32, weight);
    }
    Some((t, nodes))
}

/// Shared body of the conservative policies: walk `order`, place every job
/// (now or in the future), report the ones starting now.
fn conservative_schedule(now: Time, order: &[&PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
    let mut starts = Vec::new();
    for job in order {
        if let Some((t, nodes)) = place_conservative(now, job, gantt) {
            if t == now {
                starts.push((job.id, nodes));
            }
        }
        // No placement = impossible request (too many nodes / no eligible
        // resources); the meta-scheduler turns those into Error jobs.
    }
    starts
}

/// OAR default policy.
pub struct FifoConservative;

impl QueuePolicy for FifoConservative {
    fn name(&self) -> &'static str {
        "fifo_conservative"
    }

    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
        let mut order: Vec<&PolicyJob> = jobs.iter().collect();
        order.sort_by_key(|j| (j.submission_time, j.id));
        conservative_schedule(now, &order, gantt)
    }
}

/// OAR(2): increasing number of required resources (Table 3, last column).
pub struct SjfConservative;

impl QueuePolicy for SjfConservative {
    fn name(&self) -> &'static str {
        "sjf_conservative"
    }

    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
        let mut order: Vec<&PolicyJob> = jobs.iter().collect();
        order.sort_by_key(|j| (j.total_procs(), j.submission_time, j.id));
        conservative_schedule(now, &order, gantt)
    }
}

/// Best-effort queue (§3.3): start only on resources idle for the whole
/// requested window *starting now*; never reserve the future.
pub struct BestEffortPolicy;

impl QueuePolicy for BestEffortPolicy {
    fn name(&self) -> &'static str {
        "best_effort"
    }

    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
        let mut starts = Vec::new();
        let mut order: Vec<&PolicyJob> = jobs.iter().collect();
        order.sort_by_key(|j| (j.submission_time, j.id));
        for job in order {
            let avail = gantt.available_nodes_at(&job.eligible, job.weight, now, job.duration);
            if avail.len() >= job.nb_nodes as usize {
                let nodes = avail[..job.nb_nodes as usize].to_vec();
                for n in &nodes {
                    gantt.occupy(job.id, *n, job.weight, now, now + job.duration);
                }
                starts.push((job.id, nodes));
            }
        }
        starts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: JobId, nb_nodes: u32, dur: Time, sub: Time) -> PolicyJob {
        PolicyJob {
            id,
            nb_nodes,
            weight: 1,
            duration: dur,
            submission_time: sub,
            eligible: vec![1, 2, 3, 4],
            best_effort: false,
            score: 0.0,
            alts: vec![],
        }
    }

    fn gantt4() -> Gantt {
        Gantt::new(&[(1, 1), (2, 1), (3, 1), (4, 1)])
    }

    #[test]
    fn fifo_starts_in_order() {
        let g = &mut gantt4();
        let jobs = vec![job(1, 2, 100, 0), job(2, 2, 100, 1)];
        let starts = FifoConservative.schedule(0, &jobs, g);
        assert_eq!(starts.len(), 2, "4 procs fit both 2-proc jobs");
    }

    #[test]
    fn fifo_is_conservative_no_job_delayed_by_later() {
        let g = &mut gantt4();
        // j1 takes all 4 nodes for 100s; j2 (2 nodes) must come after;
        // j3 (2 nodes, shorter) must NOT jump ahead of j2's reservation
        // if that would delay it — here it can coexist with j2, so it may
        // backfill alongside.
        let jobs = vec![job(1, 4, 100, 0), job(2, 2, 50, 1), job(3, 2, 50, 2)];
        let starts = FifoConservative.schedule(0, &jobs, g);
        assert_eq!(starts.len(), 1);
        assert_eq!(starts[0].0, 1);
        // j2 reserved at t=100, j3 backfills beside it at t=100 as well
        // (2+2 procs): check the gantt placed everything.
        assert_eq!(g.allocations().len(), 4 + 2 + 2);
        assert_eq!(g.makespan(), 150);
    }

    #[test]
    fn fifo_backfill_cannot_delay_earlier_job() {
        let mut g = Gantt::new(&[(1, 1), (2, 1)]);
        // running job holds node 1 for 100s
        g.occupy(99, 1, 1, 0, 100);
        // j1 wants both nodes -> reserved at t=100.
        // j2 wants 1 node for 200s: starting it now on node 2 would delay
        // j1; conservative placement puts it at t=100.. after j1? No:
        // j1 occupies [100, 150) on both; j2 (200s) earliest on node 2 is
        // t=150? Actually node 2 free during [0,100) but only 100s < 200s.
        let jobs = vec![job(1, 2, 50, 0), job(2, 1, 200, 1)];
        let starts = FifoConservative.schedule(0, &jobs, &mut g);
        assert!(starts.is_empty(), "nothing can start now: {starts:?}");
        // j2 must start at 150, not 0.
        let allocs = g.allocations();
        let j2: Vec<_> = allocs.iter().filter(|(_, a)| a.job == 2).collect();
        assert_eq!(j2.len(), 1);
        assert_eq!(j2[0].1.start, 150);
    }

    #[test]
    fn fifo_short_job_backfills_into_hole() {
        let mut g = Gantt::new(&[(1, 1), (2, 1)]);
        g.occupy(99, 1, 1, 0, 100);
        // j1 wants both nodes (reserved at 100); j2 is short enough to fit
        // in node 2's idle window before 100 -> genuine backfill, starts now.
        let jobs = vec![job(1, 2, 50, 0), job(2, 1, 60, 1)];
        let starts = FifoConservative.schedule(0, &jobs, &mut g);
        assert_eq!(starts, vec![(2, vec![2])]);
    }

    #[test]
    fn sjf_orders_by_size() {
        let g = &mut gantt4();
        // Big job first in FIFO, but SJF runs the small one first.
        let jobs = vec![job(1, 4, 100, 0), job(2, 1, 100, 1)];
        let starts = SjfConservative.schedule(0, &jobs, g);
        assert_eq!(starts.len(), 1);
        assert_eq!(starts[0].0, 2, "SJF starts the 1-node job first");
    }

    #[test]
    fn best_effort_never_reserves_future() {
        let mut g = gantt4();
        g.occupy(99, 1, 1, 0, 10);
        g.occupy(99, 2, 1, 0, 10);
        g.occupy(99, 3, 1, 0, 10);
        g.occupy(99, 4, 1, 0, 10);
        let jobs = vec![job(1, 1, 100, 0)];
        let starts = BestEffortPolicy.schedule(0, &jobs, &mut g);
        assert!(starts.is_empty());
        // Nothing placed in the future either:
        assert!(g.allocations().iter().all(|(_, a)| a.job == 99));
    }

    #[test]
    fn best_effort_fills_idle_nodes() {
        let mut g = gantt4();
        g.occupy(99, 1, 1, 0, 1000);
        let jobs = vec![job(1, 2, 100, 0), job(2, 2, 100, 1)];
        let starts = BestEffortPolicy.schedule(0, &jobs, &mut g);
        // 3 idle nodes: first job takes 2, second finds only 1 -> skipped.
        assert_eq!(starts.len(), 1);
        assert_eq!(starts[0].1.len(), 2);
    }

    #[test]
    fn impossible_jobs_are_skipped_not_fatal() {
        let g = &mut gantt4();
        let mut j = job(1, 8, 10, 0); // more nodes than exist
        j.eligible = vec![1, 2, 3, 4];
        let starts = FifoConservative.schedule(0, &[j, job(2, 1, 10, 1)], g);
        assert_eq!(starts.len(), 1);
        assert_eq!(starts[0].0, 2);
    }

    #[test]
    fn eligibility_restricts_placement() {
        let g = &mut gantt4();
        let mut j = job(1, 1, 10, 0);
        j.eligible = vec![3];
        let starts = FifoConservative.schedule(0, &[j], g);
        assert_eq!(starts, vec![(1, vec![3])]);
    }

    fn alt(switches: Option<u32>, hosts: u32, cores: u32) -> AltShape {
        AltShape {
            shape: Shape { switches, hosts, cores },
            eligible: None,
        }
    }

    #[test]
    fn moldable_job_falls_through_to_the_feasible_alternative() {
        // Two 4-proc nodes. First alternative (/host=4/core=2) needs 4
        // hosts — impossible; second (/host=2/core=4) fits now. The job
        // row mirrors the first alternative, so the placement is a
        // reshape and must be recorded.
        let mut g = Gantt::new(&[(1, 4), (2, 4)]);
        let mut j = job(1, 4, 100, 0);
        j.weight = 2;
        j.eligible = vec![1, 2];
        j.alts = vec![alt(None, 4, 2), alt(None, 2, 4)];
        let starts = FifoConservative.schedule(0, &[j], &mut g);
        assert_eq!(starts, vec![(1, vec![1, 2])]);
        assert_eq!(g.take_reshapes(), vec![(1, 2, 4)]);
        // Both nodes fully occupied by the chosen 2×4 shape.
        assert_eq!(g.busy_procs_at(50), 8);
    }

    #[test]
    fn moldable_job_picks_the_earliest_alternative() {
        // Node 1 (4 procs) busy until 100; nodes 2,3 (2 procs) free.
        // /host=1/core=4 must wait for node 1; /host=2/core=2 runs now.
        let mut g = Gantt::new(&[(1, 4), (2, 2), (3, 2)]);
        g.occupy(99, 1, 4, 0, 100);
        let mut j = job(1, 1, 50, 0);
        j.weight = 4;
        j.eligible = vec![1, 2, 3];
        j.alts = vec![alt(None, 1, 4), alt(None, 2, 2)];
        let starts = FifoConservative.schedule(0, &[j], &mut g);
        assert_eq!(starts, vec![(1, vec![2, 3])]);
        assert_eq!(g.take_reshapes(), vec![(1, 2, 2)]);
    }

    #[test]
    fn switch_constrained_alternative_uses_the_hierarchy() {
        use crate::resources::{Hierarchy, TreeHost, TreeSwitch};
        // sw1 = {1, 2}, sw2 = {3, 4}; node 1 busy until 50, so the only
        // same-switch pair free now is sw2's.
        let mut g = gantt4();
        g.set_hierarchy(Hierarchy {
            switches: vec![
                TreeSwitch {
                    name: "sw1".into(),
                    hosts: vec![TreeHost { node: 1, procs: 1 }, TreeHost { node: 2, procs: 1 }],
                },
                TreeSwitch {
                    name: "sw2".into(),
                    hosts: vec![TreeHost { node: 3, procs: 1 }, TreeHost { node: 4, procs: 1 }],
                },
            ],
        });
        g.occupy(99, 1, 1, 0, 50);
        let mut j = job(1, 2, 100, 0);
        j.alts = vec![alt(Some(1), 2, 1)];
        let starts = FifoConservative.schedule(0, &[j], &mut g);
        assert_eq!(starts, vec![(1, vec![3, 4])]);
        assert!(g.take_reshapes().is_empty(), "shape matches the job row");
    }

    #[test]
    fn moldable_with_no_feasible_alternative_places_nothing() {
        let mut g = Gantt::new(&[(1, 2)]);
        let mut j = job(1, 1, 10, 0);
        j.eligible = vec![1];
        j.alts = vec![alt(None, 4, 1), alt(None, 1, 8)];
        let starts = FifoConservative.schedule(0, &[j], &mut g);
        assert!(starts.is_empty());
        assert!(g.allocations().is_empty());
    }
}
