//! The meta-scheduler (§2.3): "manages reservations and schedule each
//! queue using its own scheduler. This module maintains an internal
//! representation of the available ressources similar to a Gantt diagram
//! ... The whole algorithm schedules each queue in turn by decreasing
//! priority using it associated scheduler."
//!
//! One [`MetaScheduler::round`] call is one execution of the paper's
//! scheduling module: read everything from the database, compute, and
//! return the decisions. The round itself never writes — it runs against
//! a shared read guard of the store, so status queries proceed while a
//! round is planning; the caller applies the decision (state transitions,
//! assignments, reservation grants) under the write lock. The module
//! keeps no hidden state between rounds (re-running it is always safe —
//! the central module's redundancy principle).

use crate::db::Db;
use crate::matching::encode::{Encoder, JobToMatch};
use crate::matching::{shapes, ScheduleStep, SqlMatcher};
use crate::types::{
    Job, JobId, JobState, NodeId, QueuePolicyKind, ReservationField, Time,
};
use crate::Result;

use crate::resources::Shape;

use super::gantt::Gantt;
use super::policies::{
    AltShape, BestEffortPolicy, FifoConservative, PolicyJob, QueuePolicy, SjfConservative,
};

/// Meta-scheduler tunables.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Horizon slot length for the dense matching path.
    pub slot_secs: Time,
    /// Use the dense (kernel) matching engine for eligibility; SQL-match
    /// only the fallback jobs. When false, everything goes the SQL path
    /// (the paper's original behaviour).
    pub dense_matching: bool,
    /// Priority-score weights fed to the kernel (feature order: wait-time,
    /// queue priority, total procs, duration, best-effort, bias).
    pub score_weights: [f32; shapes::F],
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            slot_secs: shapes::DEFAULT_SLOT_SECS,
            dense_matching: true,
            score_weights: [1.0, 10.0, 0.0, 0.0, -5.0, 0.0],
        }
    }
}

/// Everything one round decided; the caller (central module / simulator)
/// turns these into launcher work and user notifications.
#[derive(Debug, Default, Clone)]
pub struct SchedulerDecision {
    /// Jobs to start now, with their node assignments.
    pub starts: Vec<(JobId, Vec<NodeId>)>,
    /// Running best-effort jobs whose resources were reclaimed (§3.3).
    pub cancellations: Vec<JobId>,
    /// Jobs that can never run (no eligible resources): → Error.
    pub rejected: Vec<(JobId, String)>,
    /// `toSchedule` reservations granted a slot this round, with the
    /// chosen nodes; the caller pins the assignment and flips the
    /// reservation to `Scheduled` when it applies the decision.
    pub reservations_confirmed: Vec<(JobId, Vec<NodeId>)>,
    /// `toSchedule` reservations that could not be granted: → Error.
    pub reservations_rejected: Vec<JobId>,
    /// Moldable jobs among `starts` whose winning alternative differs
    /// from the stored `nbNodes × weight`: `(job, nb_nodes, weight)`
    /// for the caller to persist *before* writing the assignment.
    pub reshapes: Vec<(JobId, u32, u32)>,
}

/// The meta-scheduler module.
pub struct MetaScheduler {
    config: SchedulerConfig,
    engine: Box<dyn ScheduleStep>,
    /// Vocabulary cache; rebuilt when the fleet changes.
    encoder_fleet_len: usize,
    encoder: Option<Encoder>,
}

impl MetaScheduler {
    pub fn new(config: SchedulerConfig, engine: Box<dyn ScheduleStep>) -> MetaScheduler {
        MetaScheduler {
            config,
            engine,
            encoder_fleet_len: 0,
            encoder: None,
        }
    }

    /// Convenience: SQL-only matching with default config.
    pub fn sql_only() -> MetaScheduler {
        MetaScheduler::new(
            SchedulerConfig {
                dense_matching: false,
                ..Default::default()
            },
            Box::new(crate::matching::ReferenceStep),
        )
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.config
    }

    /// One scheduling round over the database state at `now`. Read-only:
    /// `db` may be a shared read guard; concurrent status queries are
    /// never blocked by a planning round.
    pub fn round(&mut self, db: &Db, now: Time) -> Result<SchedulerDecision> {
        let mut decision = SchedulerDecision::default();
        let nodes = db.alive_nodes();
        // The *registered* fleet (any state) judges impossibility: a job
        // blocked only by a transient node failure stays Waiting; a job no
        // fleet configuration could ever satisfy becomes an Error.
        let fleet = db.all_nodes();
        let node_caps: Vec<(NodeId, u32)> = nodes.iter().map(|n| (n.id, n.nb_procs)).collect();
        let mut gantt = Gantt::new(&node_caps);
        // Placement tree for hierarchical requests: the resources table
        // when populated, or the nodes' `switch` property otherwise.
        gantt.set_hierarchy(db.hierarchy());

        // 1. Occupy resources of live regular jobs (running best-effort
        //    jobs are deliberately left out: they are pre-emptable, §3.3).
        let mut running_best_effort: Vec<Job> = Vec::new();
        for state in [JobState::ToLaunch, JobState::Launching, JobState::Running] {
            // O(1) view probe before the row fetch: most rounds on a
            // quiet cluster have nothing in most holding states.
            if db.state_depth(state) == 0 {
                continue;
            }
            for job in db.jobs_in_state(state) {
                let stop = expected_stop(&job, now);
                if job.best_effort {
                    running_best_effort.push(job);
                    continue;
                }
                for node in db.assigned_nodes(job.id) {
                    gantt.occupy(job.id, node, job.weight, now, stop);
                }
            }
        }

        // 2. Confirmed reservations hold their future slots; due ones start.
        for job in db.jobs_in_state(JobState::Waiting) {
            if job.reservation != ReservationField::Scheduled {
                continue;
            }
            let start = job.reservation_start.unwrap_or(now);
            let assigned = db.assigned_nodes(job.id);
            if start <= now {
                for node in &assigned {
                    gantt.occupy(job.id, *node, job.weight, now, now + job.max_time);
                }
                decision.starts.push((job.id, assigned));
            } else {
                for node in &assigned {
                    gantt.occupy(job.id, *node, job.weight, start, start + job.max_time);
                }
            }
        }

        // 3. Negotiate new reservations (`toSchedule` → `Scheduled`/Error).
        for job in db.jobs_in_state(JobState::Waiting) {
            if job.reservation != ReservationField::ToSchedule {
                continue;
            }
            let start = job.reservation_start.unwrap_or(now).max(now);
            let eligible = SqlMatcher::eligible_nodes(&job.properties, &nodes)?;
            let avail = gantt.available_nodes_at(&eligible, job.weight, start, job.max_time);
            if avail.len() >= job.nb_nodes as usize {
                let chosen = avail[..job.nb_nodes as usize].to_vec();
                for n in &chosen {
                    gantt.occupy(job.id, *n, job.weight, start, start + job.max_time);
                }
                decision.reservations_confirmed.push((job.id, chosen));
            } else {
                decision.reservations_rejected.push(job.id);
            }
        }

        // 4. Schedule each regular queue in decreasing priority.
        let queues = db.queues_by_priority();
        // Minimal-preemption heuristic input, hoisted out of the queue
        // loop: the nodes hosting running best-effort work (one indexed
        // assignments probe per best-effort job, once per round).
        let be_nodes: std::collections::BTreeSet<NodeId> = running_best_effort
            .iter()
            .flat_map(|j| db.assigned_nodes(j.id))
            .collect();
        let mut best_effort_queues = Vec::new();
        for queue in &queues {
            if !queue.active {
                continue;
            }
            if queue.policy == QueuePolicyKind::BestEffort {
                best_effort_queues.push(queue.clone());
                continue;
            }
            // The queue_depth view answers the common case — an empty
            // queue — without fetching or decoding a single job row.
            if db.queue_depth(&queue.name) == 0 {
                continue;
            }
            let waiting: Vec<Job> = db
                .waiting_jobs_in_queue(&queue.name)
                .into_iter()
                .filter(|j| j.reservation == ReservationField::None)
                .collect();
            if waiting.is_empty() {
                continue;
            }
            let mut policy_jobs =
                self.build_policy_jobs(db, &waiting, &nodes, &gantt, queue.priority, now)?;
            // Minimal-preemption heuristic: prefer nodes that do not host
            // running best-effort work, so reclamation (§3.3) only happens
            // when genuinely necessary.
            if !be_nodes.is_empty() {
                for pj in &mut policy_jobs {
                    pj.eligible.sort_by_key(|n| (be_nodes.contains(n), *n));
                }
            }
            let (feasible, impossible) = split_impossible(policy_jobs, &waiting, &fleet);
            for (id, why) in impossible {
                decision.rejected.push((id, why));
            }
            let policy = policy_for(queue.policy);
            let starts = policy.schedule(now, &feasible, &mut gantt);
            decision.starts.extend(starts);
        }

        // 5. Best-effort reclamation (§3.3): a running best-effort job
        //    survives only if its allocation still fits next to everything
        //    placed above; otherwise the scheduler requests cancellation.
        for job in &running_best_effort {
            let assigned = db.assigned_nodes(job.id);
            let stop = expected_stop(job, now);
            let fits = assigned
                .iter()
                .all(|n| gantt.free_at(*n, now) >= job.weight as i64)
                && !assigned.is_empty();
            if fits {
                for node in &assigned {
                    gantt.occupy(job.id, *node, job.weight, now, stop);
                }
            } else {
                decision.cancellations.push(job.id);
            }
        }

        // 6. Best-effort queues fill whatever is idle right now.
        for queue in &best_effort_queues {
            if db.queue_depth(&queue.name) == 0 {
                continue;
            }
            let waiting: Vec<Job> = db.waiting_jobs_in_queue(&queue.name);
            if waiting.is_empty() {
                continue;
            }
            let policy_jobs =
                self.build_policy_jobs(db, &waiting, &nodes, &gantt, queue.priority, now)?;
            let (feasible, impossible) = split_impossible(policy_jobs, &waiting, &fleet);
            for (id, why) in impossible {
                decision.rejected.push((id, why));
            }
            let starts = BestEffortPolicy.schedule(now, &feasible, &mut gantt);
            decision.starts.extend(starts);
        }

        // 7. Persist the winning moldable shape only for jobs that start
        //    now: future placements are re-planned from scratch next round
        //    (no hidden state), so their reshapes are discarded.
        let started: std::collections::BTreeSet<JobId> =
            decision.starts.iter().map(|s| s.0).collect();
        decision.reshapes = gantt
            .take_reshapes()
            .into_iter()
            .filter(|(id, _, _)| started.contains(id))
            .collect();

        Ok(decision)
    }

    /// Resource matching for one queue's waiting jobs: dense engine in
    /// J-sized chunks with SQL fallback, or pure SQL.
    fn build_policy_jobs(
        &mut self,
        db: &Db,
        waiting: &[Job],
        nodes: &[crate::types::Node],
        gantt: &Gantt,
        queue_priority: i32,
        now: Time,
    ) -> Result<Vec<PolicyJob>> {
        let mut out = Vec::with_capacity(waiting.len());
        if !self.config.dense_matching || nodes.len() > shapes::N {
            for job in waiting {
                let eligible = SqlMatcher::eligible_nodes(&job.properties, nodes)?;
                out.push(to_policy_job(job, eligible, nodes)?);
            }
            let _ = db;
            return Ok(out);
        }

        if self.encoder.is_none() || self.encoder_fleet_len != nodes.len() {
            self.encoder = Some(Encoder::from_nodes(nodes));
            self.encoder_fleet_len = nodes.len();
        }
        let encoder = self.encoder.as_ref().unwrap();
        let node_ids: Vec<NodeId> = nodes.iter().map(|n| n.id).collect();
        let node_free = gantt.free_matrix(&node_ids, now, self.config.slot_secs, shapes::T);

        for chunk in waiting.chunks(shapes::J) {
            let to_match: Vec<JobToMatch> = chunk
                .iter()
                .map(|j| JobToMatch {
                    id: j.id,
                    properties: j.properties.clone(),
                    total_procs: j.total_procs(),
                    duration: j.max_time,
                    wait_time: now - j.submission_time,
                    queue_priority,
                    best_effort: j.best_effort,
                })
                .collect();
            let batch = encoder.encode(
                &to_match,
                nodes,
                &node_free,
                self.config.slot_secs,
                self.config.score_weights,
            );
            let output = self.engine.run(&batch.input)?;
            for (row, job) in chunk.iter().enumerate() {
                let eligible = if batch.fallback.contains(&job.id) {
                    SqlMatcher::eligible_nodes(&job.properties, nodes)?
                } else {
                    batch
                        .node_cols
                        .iter()
                        .enumerate()
                        .filter(|(col, _)| output.elig[row * shapes::N + col] == 1.0)
                        .map(|(_, id)| *id)
                        .collect()
                };
                let mut pj = to_policy_job(job, eligible, nodes)?;
                pj.score = output.scores[row];
                out.push(pj);
            }
        }
        Ok(out)
    }
}

/// Expected stop time used for Gantt occupation of a live job.
fn expected_stop(job: &Job, now: Time) -> Time {
    let base = job.start_time.unwrap_or(now);
    (base + job.max_time).max(now + 1)
}

/// Does the parsed request need the moldable/hierarchical placement
/// path, or is the flat `nbNodes × weight` desugar already equivalent?
fn needs_alts(req: &crate::resources::ResourceRequest) -> bool {
    req.alternatives.len() > 1
        || req.alternatives.iter().any(|a| {
            a.properties.is_some() || a.shape().is_ok_and(|s| s.switches.is_some())
        })
}

fn to_policy_job(
    job: &Job,
    eligible: Vec<NodeId>,
    nodes: &[crate::types::Node],
) -> Result<PolicyJob> {
    // Admission stores the canonical printed form, so parsing here can
    // only fail on a row edited behind the system's back — which falls
    // back to the flat shape admission derived, never a crash.
    let mut alts = Vec::new();
    if let Some(Ok(req)) = job.resources.as_deref().map(crate::resources::parse_request) {
        if needs_alts(&req) {
            for a in &req.alternatives {
                let Ok(shape) = a.shape() else { continue };
                let alt_eligible = match &a.properties {
                    Some(props) => {
                        // The alternative's `{filter}` narrows the
                        // job-level eligible set.
                        let mut e = SqlMatcher::eligible_nodes(props, nodes)?;
                        e.retain(|n| eligible.contains(n));
                        Some(e)
                    }
                    None => None,
                };
                alts.push(AltShape { shape, eligible: alt_eligible });
            }
        }
    }
    Ok(PolicyJob {
        id: job.id,
        nb_nodes: job.nb_nodes,
        weight: job.weight,
        duration: job.max_time.max(1),
        submission_time: job.submission_time,
        eligible,
        best_effort: job.best_effort,
        score: 0.0,
        alts,
    })
}

/// Jobs that no configuration of the *registered* fleet could ever run
/// are split off for rejection: fewer property-matching registered nodes
/// than `nbNodes`, or `weight` exceeding every matching node's processor
/// count — checked against fleet *capacity*, not current load or node
/// state, so a job blocked only by a transient failure keeps Waiting.
///
/// A moldable job is judged by its *minimum* requirement: it is
/// impossible only when **no** alternative fits the registered fleet.
/// Alternative-level `{filter}`s are ignored here — that can only keep
/// a doomed job Waiting, never wrongly error a feasible one.
fn split_impossible(
    jobs: Vec<PolicyJob>,
    waiting: &[Job],
    fleet: &[crate::types::Node],
) -> (Vec<PolicyJob>, Vec<(JobId, String)>) {
    let mut feasible = Vec::with_capacity(jobs.len());
    let mut impossible = Vec::new();
    // id → properties lookup once, instead of an O(jobs²) find per job.
    let props: std::collections::BTreeMap<JobId, &str> = waiting
        .iter()
        .map(|w| (w.id, w.properties.as_str()))
        .collect();
    for job in jobs {
        let properties = props.get(&job.id).copied().unwrap_or("");
        let expr = crate::db::Expr::parse(properties).ok();
        let verdict = if job.alts.is_empty() {
            let capable = capable_count(fleet, &expr, job.weight);
            (capable < job.nb_nodes as usize).then(|| {
                format!(
                    "unsatisfiable: {} capable nodes < nbNodes {}",
                    capable, job.nb_nodes
                )
            })
        } else {
            let possible = job.alts.iter().any(|a| alt_fits_fleet(fleet, &expr, &a.shape));
            (!possible)
                .then(|| "unsatisfiable: no alternative fits the registered fleet".to_string())
        };
        match verdict {
            Some(why) => impossible.push((job.id, why)),
            None => feasible.push(job),
        }
    }
    (feasible, impossible)
}

/// Registered nodes matching `expr` with at least `weight` processors.
/// An unparseable expression matches nothing (the pre-existing rule).
fn capable_count(fleet: &[crate::types::Node], expr: &Option<crate::db::Expr>, weight: u32) -> usize {
    match expr {
        Some(e) => fleet
            .iter()
            .filter(|n| n.nb_procs >= weight && e.matches(&n.property_row()))
            .count(),
        None => 0,
    }
}

/// Could any configuration of the registered fleet hold `shape`? For
/// switch-constrained shapes this demands `switches` distinct `switch`
/// property values each with at least `hosts` capable nodes.
fn alt_fits_fleet(
    fleet: &[crate::types::Node],
    expr: &Option<crate::db::Expr>,
    shape: &Shape,
) -> bool {
    let Some(total_hosts) = shape.total_hosts() else {
        return false;
    };
    let capable = |n: &&crate::types::Node| {
        n.nb_procs >= shape.cores
            && expr.as_ref().is_some_and(|e| e.matches(&n.property_row()))
    };
    match shape.switches {
        None => fleet.iter().filter(capable).count() >= total_hosts as usize,
        Some(s) => {
            let mut per_switch: std::collections::BTreeMap<&str, usize> =
                std::collections::BTreeMap::new();
            for n in fleet.iter().filter(capable) {
                let sw = n
                    .properties
                    .get("switch")
                    .and_then(crate::db::Value::as_str)
                    .unwrap_or("sw0");
                *per_switch.entry(sw).or_default() += 1;
            }
            per_switch
                .values()
                .filter(|&&c| c >= shape.hosts as usize)
                .count()
                >= s as usize
        }
    }
}

/// Instantiate the per-queue scheduler for a policy kind.
pub fn policy_for(kind: QueuePolicyKind) -> Box<dyn QueuePolicy> {
    match kind {
        QueuePolicyKind::FifoConservative => Box::new(FifoConservative),
        QueuePolicyKind::SjfConservative => Box::new(SjfConservative),
        QueuePolicyKind::BestEffort => Box::new(BestEffortPolicy),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Value;
    use crate::matching::ReferenceStep;
    use crate::types::{JobSpec, Node, Queue};

    fn setup(nodes: u32, procs: u32) -> Db {
        let mut db = Db::with_standard_queues();
        for i in 1..=nodes {
            db.add_node(
                Node::new(i, &format!("node-{i}"), procs)
                    .with_prop("mem", Value::Int(512))
                    .with_prop("cpu_mhz", Value::Int(2400)),
            );
        }
        db
    }

    fn submit(db: &mut Db, spec: JobSpec, now: Time) -> JobId {
        db.insert_job(Job::from_spec(&spec, now))
    }

    fn dense_meta() -> MetaScheduler {
        MetaScheduler::new(SchedulerConfig::default(), Box::new(ReferenceStep))
    }

    /// Apply granted reservations the way the central module does: pin
    /// the chosen nodes and flip the reservation to `Scheduled`.
    fn apply_reservations(db: &mut Db, decision: &SchedulerDecision) {
        for (id, nodes) in &decision.reservations_confirmed {
            let weight = db.job(*id).unwrap().weight;
            db.assign_nodes(*id, nodes, weight);
            db.set_job_reservation(*id, ReservationField::Scheduled)
                .unwrap();
        }
    }

    fn apply_starts(db: &mut Db, decision: &SchedulerDecision, now: Time) {
        for (id, nodes) in &decision.starts {
            let job = db.job(*id).unwrap();
            if job.reservation == ReservationField::None {
                db.assign_nodes(*id, nodes, job.weight);
            }
            db.set_job_state(*id, JobState::ToLaunch, now).unwrap();
        }
    }

    #[test]
    fn schedules_waiting_jobs_onto_free_nodes() {
        let mut db = setup(4, 2);
        let j1 = submit(&mut db, JobSpec::batch("a", "x", 2, 600), 0);
        let j2 = submit(&mut db, JobSpec::batch("b", "y", 2, 600), 1);
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 10).unwrap();
        let ids: Vec<JobId> = d.starts.iter().map(|s| s.0).collect();
        assert_eq!(ids, vec![j1, j2], "4 nodes fit both 2-node jobs");
        assert!(d.cancellations.is_empty());
        assert!(d.rejected.is_empty());
    }

    #[test]
    fn dense_and_sql_matching_agree_on_starts() {
        for dense in [true, false] {
            let mut db = setup(4, 2);
            submit(
                &mut db,
                JobSpec {
                    properties: Some("mem >= 256".into()),
                    ..JobSpec::batch("a", "x", 2, 600)
                },
                0,
            );
            submit(
                &mut db,
                JobSpec {
                    properties: Some("mem >= 1024".into()),
                    ..JobSpec::batch("b", "y", 1, 600)
                },
                1,
            );
            let mut meta = MetaScheduler::new(
                SchedulerConfig {
                    dense_matching: dense,
                    ..Default::default()
                },
                Box::new(ReferenceStep),
            );
            let d = meta.round(&mut db, 5).unwrap();
            assert_eq!(d.starts.len(), 1, "dense={dense}");
            assert_eq!(d.rejected.len(), 1, "mem>=1024 impossible, dense={dense}");
        }
    }

    #[test]
    fn respects_running_jobs() {
        let mut db = setup(2, 1);
        let j1 = submit(&mut db, JobSpec::batch("a", "x", 2, 1000), 0);
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        apply_starts(&mut db, &d, 0);
        db.set_job_state(j1, JobState::Launching, 0).unwrap();
        db.set_job_state(j1, JobState::Running, 0).unwrap();
        // second job now waits until j1's expected stop
        let _j2 = submit(&mut db, JobSpec::batch("b", "y", 1, 100), 1);
        let d = meta.round(&mut db, 2).unwrap();
        assert!(d.starts.is_empty(), "both procs busy: {:?}", d.starts);
    }

    #[test]
    fn impossible_job_is_rejected_not_stuck() {
        let mut db = setup(2, 1);
        let j = submit(&mut db, JobSpec::batch("a", "x", 5, 100), 0);
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        assert_eq!(d.rejected.len(), 1);
        assert_eq!(d.rejected[0].0, j);
    }

    #[test]
    fn weight_above_capacity_is_rejected() {
        let mut db = setup(2, 2);
        let spec = JobSpec {
            weight: 4,
            ..JobSpec::batch("a", "x", 1, 100)
        };
        let j = submit(&mut db, spec, 0);
        let d = dense_meta().round(&mut db, 0).unwrap();
        assert_eq!(d.rejected[0].0, j);
    }

    #[test]
    fn reservation_negotiation_confirms_and_rejects() {
        let mut db = setup(2, 1);
        let ok = submit(
            &mut db,
            JobSpec {
                reservation_start: Some(1000),
                ..JobSpec::batch("a", "x", 2, 600)
            },
            0,
        );
        let clash = submit(
            &mut db,
            JobSpec {
                reservation_start: Some(1200),
                ..JobSpec::batch("b", "y", 2, 600)
            },
            0,
        );
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        let confirmed: Vec<JobId> = d.reservations_confirmed.iter().map(|r| r.0).collect();
        assert_eq!(confirmed, vec![ok]);
        assert_eq!(d.reservations_rejected, vec![clash]);
        apply_reservations(&mut db, &d);
        assert_eq!(db.job(ok).unwrap().reservation, ReservationField::Scheduled);
    }

    #[test]
    fn confirmed_reservation_blocks_overlapping_work() {
        let mut db = setup(1, 1);
        let res = submit(
            &mut db,
            JobSpec {
                reservation_start: Some(100),
                ..JobSpec::batch("a", "x", 1, 1000)
            },
            0,
        );
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        apply_reservations(&mut db, &d);
        // A long job cannot start now: it would collide with the
        // reservation at t=100. (Conservative placement puts it after.)
        let _long = submit(&mut db, JobSpec::batch("b", "y", 1, 500), 1);
        let d = meta.round(&mut db, 1).unwrap();
        assert!(d.starts.iter().all(|(id, _)| *id != res));
        assert!(d.starts.is_empty(), "{:?}", d.starts);
        // A short job fits before the reservation -> backfills.
        let short = submit(&mut db, JobSpec::batch("c", "z", 1, 50), 2);
        let d = meta.round(&mut db, 2).unwrap();
        assert_eq!(d.starts.iter().map(|s| s.0).collect::<Vec<_>>(), vec![short]);
    }

    #[test]
    fn due_reservation_starts() {
        let mut db = setup(1, 1);
        let res = submit(
            &mut db,
            JobSpec {
                reservation_start: Some(100),
                ..JobSpec::batch("a", "x", 1, 600)
            },
            0,
        );
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        apply_reservations(&mut db, &d);
        let d = meta.round(&mut db, 100).unwrap();
        assert_eq!(d.starts.len(), 1);
        assert_eq!(d.starts[0].0, res);
    }

    #[test]
    fn best_effort_runs_on_idle_and_gets_reclaimed() {
        let mut db = setup(2, 1);
        let be = submit(
            &mut db,
            JobSpec {
                queue: Some("besteffort".into()),
                best_effort: true,
                ..JobSpec::batch("grid", "seti", 2, 10_000)
            },
            0,
        );
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        assert_eq!(d.starts.len(), 1, "idle cluster -> best effort starts");
        apply_starts(&mut db, &d, 0);
        db.set_job_state(be, JobState::Launching, 0).unwrap();
        db.set_job_state(be, JobState::Running, 0).unwrap();
        // A regular job arrives needing both nodes: best effort must die.
        let reg = submit(&mut db, JobSpec::batch("u", "mpi", 2, 600), 5);
        let d = meta.round(&mut db, 5).unwrap();
        assert_eq!(d.cancellations, vec![be]);
        assert!(d.starts.iter().any(|(id, _)| *id == reg));
    }

    #[test]
    fn best_effort_survives_when_room_remains() {
        let mut db = setup(3, 1);
        let be = submit(
            &mut db,
            JobSpec {
                queue: Some("besteffort".into()),
                best_effort: true,
                ..JobSpec::batch("grid", "seti", 1, 10_000)
            },
            0,
        );
        let mut meta = dense_meta();
        let d = meta.round(&mut db, 0).unwrap();
        apply_starts(&mut db, &d, 0);
        db.set_job_state(be, JobState::Launching, 0).unwrap();
        db.set_job_state(be, JobState::Running, 0).unwrap();
        let _reg = submit(&mut db, JobSpec::batch("u", "mpi", 2, 600), 5);
        let d = meta.round(&mut db, 5).unwrap();
        assert!(d.cancellations.is_empty(), "3rd node still free");
    }

    #[test]
    fn inactive_queue_is_skipped() {
        let mut db = setup(2, 1);
        submit(&mut db, JobSpec::batch("a", "x", 1, 100), 0);
        db.set_queue_active("default", false).unwrap();
        let d = dense_meta().round(&mut db, 0).unwrap();
        assert!(d.starts.is_empty());
        db.set_queue_active("default", true).unwrap();
        let d = dense_meta().round(&mut db, 1).unwrap();
        assert_eq!(d.starts.len(), 1);
    }

    #[test]
    fn sjf_queue_policy_changes_order() {
        let mut db = setup(2, 1);
        db.add_queue(Queue::new("sjf", 50, QueuePolicyKind::SjfConservative));
        // big job first, small second; only the small one fits... both fit
        // here, so instead: 2 nodes, big = 2 nodes, small = 1 node; FIFO
        // would start big; SJF starts small first then big cannot.
        submit(
            &mut db,
            JobSpec {
                queue: Some("sjf".into()),
                ..JobSpec::batch("a", "big", 2, 100)
            },
            0,
        );
        let small = submit(
            &mut db,
            JobSpec {
                queue: Some("sjf".into()),
                ..JobSpec::batch("b", "small", 1, 100)
            },
            1,
        );
        let d = dense_meta().round(&mut db, 2).unwrap();
        let ids: Vec<JobId> = d.starts.iter().map(|s| s.0).collect();
        assert_eq!(ids, vec![small]);
    }

    #[test]
    fn moldable_request_reshapes_to_the_feasible_alternative() {
        // 2 nodes × 4 procs: the first alternative (4 hosts) cannot
        // exist; the second (2 hosts × 4 cores) fits now. The round must
        // start the job under the second shape and report the reshape.
        let mut db = setup(2, 4);
        let j = submit(
            &mut db,
            JobSpec {
                nb_nodes: 4,
                weight: 2,
                resources: Some("/host=4/core=2 | /host=2/core=4".into()),
                ..JobSpec::batch("a", "x", 4, 600)
            },
            0,
        );
        let d = dense_meta().round(&mut db, 0).unwrap();
        assert_eq!(d.starts.len(), 1, "{:?}", d.rejected);
        assert_eq!(d.starts[0].0, j);
        assert_eq!(d.starts[0].1.len(), 2);
        assert_eq!(d.reshapes, vec![(j, 2, 4)]);
    }

    #[test]
    fn moldable_job_is_impossible_only_when_every_alternative_is() {
        let mut db = setup(2, 2);
        let doomed = submit(
            &mut db,
            JobSpec {
                nb_nodes: 5,
                weight: 1,
                resources: Some("/host=5/core=1 | /host=1/core=8".into()),
                ..JobSpec::batch("a", "x", 5, 100)
            },
            0,
        );
        let saved = submit(
            &mut db,
            JobSpec {
                nb_nodes: 5,
                weight: 1,
                resources: Some("/host=5/core=1 | /host=1/core=2".into()),
                ..JobSpec::batch("b", "y", 5, 100)
            },
            1,
        );
        let d = dense_meta().round(&mut db, 0).unwrap();
        assert_eq!(d.rejected.len(), 1);
        assert_eq!(d.rejected[0].0, doomed);
        assert!(d.starts.iter().any(|(id, _)| *id == saved));
    }

    #[test]
    fn switch_demand_beyond_the_fleet_is_impossible() {
        use crate::types::Node;
        let mut db = Db::with_standard_queues();
        // 4 nodes over 2 switches (2 each).
        for i in 1..=4u32 {
            db.add_node(
                Node::new(i, &format!("n{i}"), 2)
                    .with_prop("switch", Value::Text(format!("sw{}", (i - 1) / 2 + 1))),
            );
        }
        let doomed = submit(
            &mut db,
            JobSpec {
                nb_nodes: 3,
                weight: 1,
                resources: Some("/switch=3/host=1/core=1".into()),
                ..JobSpec::batch("a", "x", 3, 100)
            },
            0,
        );
        let spread = submit(
            &mut db,
            JobSpec {
                nb_nodes: 4,
                weight: 2,
                resources: Some("/switch=2/host=2/core=2".into()),
                ..JobSpec::batch("b", "y", 4, 100)
            },
            1,
        );
        let d = dense_meta().round(&mut db, 0).unwrap();
        assert_eq!(d.rejected.iter().map(|r| r.0).collect::<Vec<_>>(), vec![doomed]);
        let start = d.starts.iter().find(|(id, _)| *id == spread).unwrap();
        assert_eq!(start.1.len(), 4, "2 switches × 2 hosts");
        assert!(d.reshapes.is_empty(), "shape matches the stored row");
    }
}
