//! Baseline schedulers of the evaluation (§3.2.1).
//!
//! The paper compares OAR against Torque, Maui(+Torque) and SGE in their
//! *default scheduling configurations* and characterizes their behaviour:
//! "the schedulers of Torque and SGE ... all the jobs requiring few
//! processors are scheduled first while all the big parallel jobs are
//! delayed until the end" (greedy throughput packers, famine for big
//! jobs); Maui adds priority scheduling with backfill. We implement those
//! *policies* on our own substrate so the shape of figs. 4–8 and Table 3
//! is reproducible — see DESIGN.md's substitution table.

use crate::types::Time;

use super::gantt::Gantt;
use super::policies::{PolicyJob, QueuePolicy, Start};

/// Greedy first-fit in FIFO order, no reservation for blocked jobs —
/// Torque's (OpenPBS 2.3) default `pbs_sched`. A blocked big job is simply
/// passed over, so small jobs flow past it for as long as they keep the
/// machine busy (the famine structure of fig. 4).
pub struct TorqueLike;

impl QueuePolicy for TorqueLike {
    fn name(&self) -> &'static str {
        "torque_like"
    }

    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
        let mut order: Vec<&PolicyJob> = jobs.iter().collect();
        order.sort_by_key(|j| (j.submission_time, j.id));
        fit_now_else_skip(now, &order, gantt)
    }
}

/// Greedy first-fit in *increasing-resource* order — SGE's default sort
/// favours small jobs even harder than Torque, which is why it posts the
/// best raw throughput in Table 3 (and the worst famine).
pub struct SgeLike;

impl QueuePolicy for SgeLike {
    fn name(&self) -> &'static str {
        "sge_like"
    }

    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
        let mut order: Vec<&PolicyJob> = jobs.iter().collect();
        order.sort_by_key(|j| (j.total_procs(), j.submission_time, j.id));
        fit_now_else_skip(now, &order, gantt)
    }
}

/// Priority (FIFO) order with EASY backfilling — Maui's default: the first
/// blocked job gets a reservation at its earliest feasible time; later
/// jobs may start now only if they do not delay that reservation (which
/// the Gantt placement enforces structurally).
pub struct MauiLike;

impl QueuePolicy for MauiLike {
    fn name(&self) -> &'static str {
        "maui_like"
    }

    fn schedule(&self, now: Time, jobs: &[PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
        let mut order: Vec<&PolicyJob> = jobs.iter().collect();
        order.sort_by_key(|j| (j.submission_time, j.id));

        let mut starts = Vec::new();
        let mut head_reserved = false;
        for job in order {
            let avail = gantt.available_nodes_at(&job.eligible, job.weight, now, job.duration);
            if avail.len() >= job.nb_nodes as usize {
                let nodes = avail[..job.nb_nodes as usize].to_vec();
                for n in &nodes {
                    gantt.occupy(job.id, *n, job.weight, now, now + job.duration);
                }
                starts.push((job.id, nodes));
            } else if !head_reserved {
                // EASY: exactly one reservation, for the first blocked job.
                if let Some((t, nodes)) = gantt.find_earliest(
                    &job.eligible,
                    job.nb_nodes,
                    job.weight,
                    job.duration,
                    now,
                ) {
                    for n in &nodes {
                        gantt.occupy(job.id, *n, job.weight, t, t + job.duration);
                    }
                    head_reserved = true;
                }
            }
            // further blocked jobs: no reservation (aggressive backfill)
        }
        starts
    }
}

/// Shared body of the greedy packers.
fn fit_now_else_skip(now: Time, order: &[&PolicyJob], gantt: &mut Gantt) -> Vec<Start> {
    let mut starts = Vec::new();
    for job in order {
        let avail = gantt.available_nodes_at(&job.eligible, job.weight, now, job.duration);
        if avail.len() >= job.nb_nodes as usize {
            let nodes = avail[..job.nb_nodes as usize].to_vec();
            for n in &nodes {
                gantt.occupy(job.id, *n, job.weight, now, now + job.duration);
            }
            starts.push((job.id, nodes));
        }
    }
    starts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::JobId;

    fn job(id: JobId, nb_nodes: u32, dur: Time, sub: Time) -> PolicyJob {
        PolicyJob {
            id,
            nb_nodes,
            weight: 1,
            duration: dur,
            submission_time: sub,
            eligible: vec![1, 2, 3, 4],
            best_effort: false,
            score: 0.0,
            alts: vec![],
        }
    }

    fn gantt4() -> Gantt {
        Gantt::new(&[(1, 1), (2, 1), (3, 1), (4, 1)])
    }

    #[test]
    fn torque_passes_over_blocked_big_job() {
        let mut g = gantt4();
        g.occupy(99, 1, 1, 0, 50);
        // j1 (4 nodes) blocked; j2 (1 node) flows past it.
        let jobs = vec![job(1, 4, 100, 0), job(2, 1, 100, 1)];
        let starts = TorqueLike.schedule(0, &jobs, &mut g);
        assert_eq!(starts.len(), 1);
        assert_eq!(starts[0].0, 2, "small job overtakes the blocked big one");
        // and no reservation exists for j1:
        assert!(g.allocations().iter().all(|(_, a)| a.job != 1));
    }

    #[test]
    fn sge_sorts_small_first_even_when_submitted_later() {
        let mut g = gantt4();
        // 3 free procs; FIFO would start j1 (3 nodes) and starve j2/j3.
        g.occupy(99, 4, 1, 0, 1000);
        let jobs = vec![job(1, 3, 100, 0), job(2, 1, 100, 1), job(3, 1, 100, 2)];
        let starts = SgeLike.schedule(0, &jobs, &mut g);
        let ids: Vec<JobId> = starts.iter().map(|s| s.0).collect();
        // j2, j3 (1 node each) start first; j1 then still fits? only 1 proc
        // left, so no.
        assert_eq!(ids, vec![2, 3]);
    }

    #[test]
    fn maui_reserves_head_and_backfills_behind_it() {
        let mut g = Gantt::new(&[(1, 1), (2, 1)]);
        g.occupy(99, 1, 1, 0, 100);
        // j1 needs both nodes -> EASY reservation at t=100.
        // j2 (1 node, 60s) fits in node 2's hole before t=100 -> backfills.
        // j3 (1 node, 200s) would delay j1 -> must NOT start.
        let jobs = vec![job(1, 2, 50, 0), job(2, 1, 60, 1), job(3, 1, 200, 2)];
        let starts = MauiLike.schedule(0, &jobs, &mut g);
        assert_eq!(starts, vec![(2, vec![2])]);
        // j1's reservation exists at exactly t=100:
        let j1: Vec<_> = g
            .allocations()
            .into_iter()
            .filter(|(_, a)| a.job == 1)
            .collect();
        assert_eq!(j1.len(), 2);
        assert!(j1.iter().all(|(_, a)| a.start == 100));
    }

    #[test]
    fn maui_only_first_blocked_job_gets_reservation() {
        let mut g = Gantt::new(&[(1, 1), (2, 1)]);
        g.occupy(99, 1, 1, 0, 100);
        g.occupy(99, 2, 1, 0, 100);
        let jobs = vec![job(1, 2, 50, 0), job(2, 2, 50, 1)];
        let _ = MauiLike.schedule(0, &jobs, &mut g);
        let reserved: Vec<JobId> = g
            .allocations()
            .into_iter()
            .filter(|(_, a)| a.job != 99)
            .map(|(_, a)| a.job)
            .collect();
        assert!(reserved.iter().all(|&j| j == 1), "only the head reserves: {reserved:?}");
    }
}
