//! The scheduling layer: Gantt resource diagram, per-queue policies, the
//! meta-scheduler (§2.3), and the baseline schedulers of the evaluation
//! (§3.2).

pub mod baselines;
pub mod gantt;
pub mod meta;
pub mod policies;

pub use gantt::{Allocation, Gantt};
pub use meta::{policy_for, MetaScheduler, SchedulerConfig, SchedulerDecision};
pub use policies::{AltShape, PolicyJob, QueuePolicy};
