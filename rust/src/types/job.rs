//! The job record of fig. 2, verbatim fields plus the best-effort flag of
//! §3.3 (the Global-computing extension adds "a property to the submitted
//! jobs (best effort or not)").


use super::{JobId, JobState, Time};

/// `jobType` field: INTERACTIVE jobs report back to a user terminal,
/// PASSIVE (batch) jobs just run their command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    Interactive,
    Passive,
}

impl JobKind {
    pub fn as_str(self) -> &'static str {
        match self {
            JobKind::Interactive => "INTERACTIVE",
            JobKind::Passive => "PASSIVE",
        }
    }
}

/// `reservation` field: substates of the reservation negotiation (§2).
/// `None` is the general case; a precise-time-slot reservation walks
/// `ToSchedule` → `Scheduled` while the job stays `Waiting` for the rest of
/// the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReservationField {
    None,
    ToSchedule,
    Scheduled,
}

impl ReservationField {
    pub fn as_str(self) -> &'static str {
        match self {
            ReservationField::None => "None",
            ReservationField::ToSchedule => "toSchedule",
            ReservationField::Scheduled => "Scheduled",
        }
    }
}

/// What a user hands to `oarsub`: the subset of fig. 2 the submitter
/// controls. Missing values are filled by the admission rules (§2.1).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub user: String,
    pub command: String,
    /// Number of nodes required (`nbNodes`).
    pub nb_nodes: u32,
    /// Processors per node (`weight`).
    pub weight: u32,
    /// Maximal execution time in seconds (`maxTime`); None = let admission
    /// rules pick the queue default.
    pub max_time: Option<Time>,
    /// SQL expression to match compatible resources (`properties`).
    pub properties: Option<String>,
    pub queue: Option<String>,
    pub kind: JobKind,
    /// Requested precise time slot (reservation start), if any.
    pub reservation_start: Option<Time>,
    pub launching_directory: String,
    /// §3.3: job may be cancelled when its resources are reclaimed.
    pub best_effort: bool,
    /// Hierarchical resource request (`-l /switch=S/host=N/core=M`,
    /// possibly moldable) in the [`crate::resources`] grammar. `None` is
    /// the flat case, which desugars to `/host=nbNodes/core=weight`.
    pub resources: Option<String>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            user: "nobody".into(),
            command: "/bin/true".into(),
            nb_nodes: 1,
            weight: 1,
            max_time: None,
            properties: None,
            queue: None,
            kind: JobKind::Passive,
            reservation_start: None,
            launching_directory: "/tmp".into(),
            best_effort: false,
            resources: None,
        }
    }
}

impl JobSpec {
    /// Convenience constructor for the common batch case.
    pub fn batch(user: &str, command: &str, nb_nodes: u32, max_time: Time) -> Self {
        JobSpec {
            user: user.into(),
            command: command.into(),
            nb_nodes,
            max_time: Some(max_time),
            ..Default::default()
        }
    }

    /// Total processors requested (`nbNodes * weight`), saturating:
    /// adversarial submissions can overflow `u32`, and a wrapped small
    /// number would sail through the queue-limit check. Admission
    /// rejects the saturated sentinel via [`JobSpec::checked_total_procs`].
    pub fn total_procs(&self) -> u32 {
        self.nb_nodes.saturating_mul(self.weight)
    }

    /// `nbNodes * weight`, or `None` when it overflows `u32`.
    pub fn checked_total_procs(&self) -> Option<u32> {
        self.nb_nodes.checked_mul(self.weight)
    }
}

/// A full row of the jobs table (fig. 2).
#[derive(Debug, Clone)]
pub struct Job {
    /// `idJob`: numeric identifier (index number in the table).
    pub id: JobId,
    pub kind: JobKind,
    /// `infoType`: machine to contact for interactive jobs.
    pub info_type: Option<String>,
    pub state: JobState,
    pub reservation: ReservationField,
    /// `message`: warnings, reason for termination...
    pub message: String,
    pub user: String,
    pub nb_nodes: u32,
    /// `weight`: processors required on each node.
    pub weight: u32,
    pub command: String,
    /// `bpid`: PID used to kill the job when needed.
    pub bpid: Option<u32>,
    pub queue_name: String,
    pub max_time: Time,
    /// `properties`: SQL expression used to match compatible resources.
    pub properties: String,
    pub launching_directory: String,
    pub submission_time: Time,
    pub start_time: Option<Time>,
    pub stop_time: Option<Time>,
    /// §3.3 extension: best-effort (Global computing) job.
    pub best_effort: bool,
    /// Requested reservation slot, when `reservation != None`.
    pub reservation_start: Option<Time>,
    /// Hierarchical resource request (canonical printed form), when the
    /// submission used the tree grammar; `nb_nodes`/`weight` hold the
    /// flat equivalent of the first alternative until the scheduler
    /// picks one.
    pub resources: Option<String>,
}

impl Job {
    /// Materialize a submission into a `Waiting` job row (the admission
    /// rules have already filled any missing spec fields).
    pub fn from_spec(spec: &JobSpec, now: Time) -> Job {
        Job {
            id: 0, // assigned by the jobs table on insert
            kind: spec.kind,
            info_type: None,
            state: JobState::Waiting,
            reservation: if spec.reservation_start.is_some() {
                ReservationField::ToSchedule
            } else {
                ReservationField::None
            },
            message: String::new(),
            user: spec.user.clone(),
            nb_nodes: spec.nb_nodes,
            weight: spec.weight,
            command: spec.command.clone(),
            bpid: None,
            queue_name: spec.queue.clone().unwrap_or_else(|| "default".into()),
            max_time: spec.max_time.unwrap_or(3600),
            properties: spec.properties.clone().unwrap_or_default(),
            launching_directory: spec.launching_directory.clone(),
            submission_time: now,
            start_time: None,
            stop_time: None,
            best_effort: spec.best_effort,
            reservation_start: spec.reservation_start,
            resources: spec.resources.clone(),
        }
    }

    /// Total processors this job occupies. Saturating for the same
    /// reason as [`JobSpec::total_procs`]: admission has already
    /// rejected genuine overflows, but a row edited behind the system's
    /// back must not wrap into a tiny claim.
    pub fn total_procs(&self) -> u32 {
        self.nb_nodes.saturating_mul(self.weight)
    }

    /// Response time as defined by the paper's §3.2.2 burst evaluation:
    /// "the difference between the termination date and the submission
    /// date of a job". None until the job terminates.
    pub fn response_time(&self) -> Option<Time> {
        self.stop_time.map(|st| st - self.submission_time)
    }

    /// Wait time: scheduling + queueing delay before execution started.
    pub fn wait_time(&self) -> Option<Time> {
        self.start_time.map(|st| st - self.submission_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        Job {
            id: 1,
            kind: JobKind::Passive,
            info_type: None,
            state: JobState::Waiting,
            reservation: ReservationField::None,
            message: String::new(),
            user: "alice".into(),
            nb_nodes: 4,
            weight: 2,
            command: "mpirun app".into(),
            bpid: None,
            queue_name: "default".into(),
            max_time: 3600,
            properties: "mem >= 512".into(),
            launching_directory: "/home/alice".into(),
            submission_time: 100,
            start_time: None,
            stop_time: None,
            best_effort: false,
            reservation_start: None,
            resources: None,
        }
    }

    #[test]
    fn total_procs_is_nodes_times_weight() {
        assert_eq!(job().total_procs(), 8);
        assert_eq!(JobSpec::batch("u", "c", 3, 60).total_procs(), 3);
    }

    #[test]
    fn total_procs_saturates_instead_of_wrapping() {
        let spec = JobSpec {
            nb_nodes: u32::MAX,
            weight: 3,
            ..JobSpec::default()
        };
        assert_eq!(spec.total_procs(), u32::MAX, "saturates, never wraps");
        assert_eq!(spec.checked_total_procs(), None);
        let mut j = job();
        j.nb_nodes = u32::MAX;
        j.weight = u32::MAX;
        assert_eq!(j.total_procs(), u32::MAX);
    }

    #[test]
    fn response_and_wait_times() {
        let mut j = job();
        assert_eq!(j.response_time(), None);
        j.start_time = Some(150);
        j.stop_time = Some(400);
        assert_eq!(j.wait_time(), Some(50));
        assert_eq!(j.response_time(), Some(300));
    }

    #[test]
    fn spec_defaults_are_minimal_single_node() {
        let s = JobSpec::default();
        assert_eq!(s.nb_nodes, 1);
        assert_eq!(s.weight, 1);
        assert!(!s.best_effort);
        assert!(s.queue.is_none());
    }
}
