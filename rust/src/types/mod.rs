//! Core domain types: jobs (fig. 2), the job state machine (fig. 1),
//! nodes, queues and reservations.

mod grid;
mod job;
mod node;
mod queue;
mod state;

pub use grid::{Campaign, CampaignId, CampaignSpec, CampaignState, GridTask, GridTaskState};
pub use job::{Job, JobKind, JobSpec, ReservationField};
pub use node::{Node, NodeState};
pub use queue::{Queue, QueuePolicyKind};
pub use state::{JobState, RecoveryPolicy};

/// Seconds since the (simulated or real) epoch. All scheduling arithmetic
/// is done on this type; the paper's tables store dates the same way.
pub type Time = i64;

/// Job identifier: the index number in the jobs table (§2.1).
pub type JobId = u64;

/// Node identifier.
pub type NodeId = u32;
