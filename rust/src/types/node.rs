//! Node (resource) records: the paper's nodes table ("a table for
//! describing nodes"), with free-form properties matched by the jobs'
//! `properties` SQL expression.

use std::collections::BTreeMap;


use super::NodeId;
use crate::db::Value;

/// Administrative / monitored state of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Reachable and accepting jobs.
    Alive,
    /// Failed the reachability test (§2.4 failure detection).
    Suspected,
    /// Administratively removed from scheduling.
    Absent,
}

impl NodeState {
    pub fn as_str(self) -> &'static str {
        match self {
            NodeState::Alive => "Alive",
            NodeState::Suspected => "Suspected",
            NodeState::Absent => "Absent",
        }
    }

    /// Inverse of [`NodeState::as_str`]; `None` for anything else.
    pub fn parse(s: &str) -> Option<NodeState> {
        match s {
            "Alive" => Some(NodeState::Alive),
            "Suspected" => Some(NodeState::Suspected),
            "Absent" => Some(NodeState::Absent),
            _ => None,
        }
    }
}

/// A row of the nodes table.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub hostname: String,
    pub state: NodeState,
    /// Processors on this node (the paper's bi-Xeon nodes have 2).
    pub nb_procs: u32,
    /// Free-form properties matched by job `properties` expressions:
    /// e.g. `mem` (MB), `switch`, `cpu_mhz`. Stored as DB values so the
    /// expression engine can compare them directly.
    pub properties: BTreeMap<String, Value>,
}

impl Node {
    /// Build a node with the standard property set used throughout the
    /// evaluation (mem, cpu_mhz, switch, nb_procs mirrored as a property).
    pub fn new(id: NodeId, hostname: &str, nb_procs: u32) -> Node {
        let mut properties = BTreeMap::new();
        properties.insert("nb_procs".into(), Value::Int(nb_procs as i64));
        Node {
            id,
            hostname: hostname.into(),
            state: NodeState::Alive,
            nb_procs,
            properties,
        }
    }

    /// Set a property, returning self for builder-style construction.
    pub fn with_prop(mut self, key: &str, value: Value) -> Node {
        self.properties.insert(key.into(), value);
        self
    }

    /// The property row the expression engine evaluates against: all node
    /// properties plus the implicit `hostname` and `state` columns. (The
    /// database's matcher avoids this materialization entirely by
    /// evaluating expressions over the stored rows through a view; this
    /// remains for callers holding typed `Node`s.)
    pub fn property_row(&self) -> crate::db::Row {
        let mut row = crate::db::Row::new();
        for (k, v) in &self.properties {
            row.insert(k.clone().into(), v.clone());
        }
        row.insert("hostname".into(), Value::Text(self.hostname.clone()));
        row.insert("state".into(), Value::Text(self.state.as_str().into()));
        row
    }

    pub fn is_alive(&self) -> bool {
        self.state == NodeState::Alive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_properties() {
        let n = Node::new(1, "node-1", 2)
            .with_prop("mem", Value::Int(512))
            .with_prop("switch", Value::Text("sw1".into()));
        assert_eq!(n.properties.get("mem"), Some(&Value::Int(512)));
        assert_eq!(n.nb_procs, 2);
        let row = n.property_row();
        assert_eq!(row.get("hostname"), Some(&Value::Text("node-1".into())));
        assert_eq!(row.get("state"), Some(&Value::Text("Alive".into())));
    }

    #[test]
    fn nb_procs_is_mirrored_as_property() {
        let n = Node::new(3, "n3", 4);
        assert_eq!(n.properties.get("nb_procs"), Some(&Value::Int(4)));
    }
}
