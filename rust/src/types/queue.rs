//! Submission queues (§2.3): each queue has its own admission rules,
//! scheduling policy and priority; queues partition jobs into groups and
//! the meta-scheduler schedules each queue in turn by decreasing priority.


use super::Time;

/// Which per-queue scheduler the meta-scheduler runs for this queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicyKind {
    /// OAR default: FIFO order with *conservative* backfilling — no job may
    /// be delayed by a later one within the queue (§3.2.1 "we do not allow
    /// jobs to be delayed within a given queue").
    FifoConservative,
    /// OAR(2) of Table 3: within-queue order changed to increasing number
    /// of required resources, still conservative.
    SjfConservative,
    /// Best-effort queue (§3.3): jobs are placed only on otherwise-idle
    /// resources and may be cancelled when those are reclaimed.
    BestEffort,
}

impl QueuePolicyKind {
    pub fn as_str(self) -> &'static str {
        match self {
            QueuePolicyKind::FifoConservative => "fifo",
            QueuePolicyKind::SjfConservative => "sjf",
            QueuePolicyKind::BestEffort => "best_effort",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fifo" => QueuePolicyKind::FifoConservative,
            "sjf" => QueuePolicyKind::SjfConservative,
            "best_effort" => QueuePolicyKind::BestEffort,
            _ => return None,
        })
    }
}

/// A row of the queues table.
#[derive(Debug, Clone)]
pub struct Queue {
    pub name: String,
    /// Higher priority queues are scheduled first (§2.3).
    pub priority: i32,
    pub policy: QueuePolicyKind,
    /// Default `maxTime` applied by admission when the user gives none.
    pub default_max_time: Time,
    /// Admission cap: max resources one job may request in this queue
    /// ("the default admission rules ... ensure that no user ask for too
    /// much resources at once", §2.1).
    pub max_procs_per_job: u32,
    /// Whether the queue is currently accepting/scheduling jobs (an entire
    /// queue "can be interrupted for some time or cancelled if needed").
    pub active: bool,
}

impl Queue {
    pub fn new(name: &str, priority: i32, policy: QueuePolicyKind) -> Queue {
        Queue {
            name: name.into(),
            priority,
            policy,
            default_max_time: 3600,
            max_procs_per_job: u32::MAX,
            active: true,
        }
    }

    /// The standard queue set used by the evaluation: `default` (FIFO),
    /// plus a `besteffort` queue at the lowest priority (§3.3).
    pub fn standard_set() -> Vec<Queue> {
        vec![
            Queue::new("default", 10, QueuePolicyKind::FifoConservative),
            Queue {
                default_max_time: 7 * 24 * 3600,
                ..Queue::new("besteffort", 0, QueuePolicyKind::BestEffort)
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_set_orders_besteffort_last() {
        let qs = Queue::standard_set();
        assert_eq!(qs.len(), 2);
        assert!(qs[0].priority > qs[1].priority);
        assert_eq!(qs[1].policy, QueuePolicyKind::BestEffort);
    }

    #[test]
    fn policy_roundtrip() {
        for p in [
            QueuePolicyKind::FifoConservative,
            QueuePolicyKind::SjfConservative,
            QueuePolicyKind::BestEffort,
        ] {
            assert_eq!(QueuePolicyKind::parse(p.as_str()), Some(p));
        }
        assert_eq!(QueuePolicyKind::parse("nope"), None);
        // Encodings are stable wire/db contract values, not Debug names.
        assert_eq!(QueuePolicyKind::FifoConservative.as_str(), "fifo");
        assert_eq!(QueuePolicyKind::SjfConservative.as_str(), "sjf");
        assert_eq!(QueuePolicyKind::BestEffort.as_str(), "best_effort");
        // Parsing is exact: no case folding, no surrounding whitespace.
        assert_eq!(QueuePolicyKind::parse("FIFO"), None);
        assert_eq!(QueuePolicyKind::parse(" fifo"), None);
        assert_eq!(QueuePolicyKind::parse(""), None);
    }

    #[test]
    fn standard_set_invariants() {
        let qs = Queue::standard_set();
        // Unique names — the queues table probes by name.
        let mut names: Vec<&str> = qs.iter().map(|q| q.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), qs.len(), "queue names must be unique");
        // Exactly one default queue, and it is the admission fallback
        // target of the `DEFAULT queue = 'default'` rule.
        assert_eq!(qs.iter().filter(|q| q.name == "default").count(), 1);
        // Exactly one best-effort queue (§3.3), and nothing outranks the
        // default queue: best-effort work may never delay normal jobs.
        assert_eq!(
            qs.iter()
                .filter(|q| q.policy == QueuePolicyKind::BestEffort)
                .count(),
            1
        );
        let default = qs.iter().find(|q| q.name == "default").unwrap();
        let besteffort = qs
            .iter()
            .find(|q| q.policy == QueuePolicyKind::BestEffort)
            .unwrap();
        assert!(default.priority > besteffort.priority);
        // Sane rows: non-negative priorities, positive default maxTime,
        // every queue active out of the box.
        for q in &qs {
            assert!(q.priority >= 0, "{}: negative priority", q.name);
            assert!(q.default_max_time > 0, "{}: bad default maxTime", q.name);
            assert!(q.max_procs_per_job > 0, "{}: zero proc cap", q.name);
            assert!(q.active, "{}: standard queues start active", q.name);
        }
        // Priorities are distinct, so the meta-scheduler's by-priority
        // iteration order is total and deterministic.
        let mut prios: Vec<i32> = qs.iter().map(|q| q.priority).collect();
        prios.sort_unstable();
        prios.dedup();
        assert_eq!(prios.len(), qs.len(), "queue priorities must be distinct");
    }
}
