//! Grid-federation domain types: campaigns (a grid-level bag of tasks)
//! and grid tasks (one remote best-effort job each), in the spirit of the
//! paper's metropolitan-GRID deployment (§ abstract: "the management of
//! 700 nodes", §3.3 global computing support). A campaign is submitted to
//! the grid meta-scheduler, which farms its tasks across clusters as
//! best-effort jobs and tracks each task's remote placement in the
//! `campaigns` / `grid_tasks` tables.

use super::{JobId, Time};

/// Campaign identifier: the index number in the campaigns table.
pub type CampaignId = u64;

/// Lifecycle of a campaign.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CampaignState {
    /// Tasks remain to dispatch or reconcile.
    Active,
    /// Every task reached a terminal state (`Done` or `Failed`).
    Done,
}

impl CampaignState {
    pub fn as_str(self) -> &'static str {
        match self {
            CampaignState::Active => "Active",
            CampaignState::Done => "Done",
        }
    }

    pub fn parse(s: &str) -> Option<CampaignState> {
        Some(match s {
            "Active" => CampaignState::Active,
            "Done" => CampaignState::Done,
            _ => return None,
        })
    }
}

/// What a user hands to `oar grid sub`: a parameterized task template.
/// Every occurrence of `{i}` in `command` is replaced by the task index
/// (0-based) at dispatch time, exactly like `oarsub --array`.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSpec {
    pub name: String,
    pub user: String,
    /// Task command template (`{i}` = task index).
    pub command: String,
    /// Nodes per task.
    pub nb_nodes: u32,
    /// Processors per node per task.
    pub weight: u32,
    /// `maxTime` per task, in seconds.
    pub max_time: Time,
    /// Number of tasks in the bag.
    pub tasks: u32,
}

impl CampaignSpec {
    /// Convenience constructor for the common single-proc-task case.
    pub fn bag(name: &str, user: &str, command: &str, tasks: u32) -> CampaignSpec {
        CampaignSpec {
            name: name.into(),
            user: user.into(),
            command: command.into(),
            nb_nodes: 1,
            weight: 1,
            max_time: 3600,
            tasks,
        }
    }
}

/// A row of the `campaigns` table.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub id: CampaignId,
    /// Globally unique random token, minted at submission. Task tags
    /// embed it instead of the campaign id: ids restart at 1 in every
    /// grid's own database, so two grids sharing a cluster (or one grid
    /// rebooted with a wiped state directory) would otherwise adopt and
    /// kill each other's jobs.
    pub token: u64,
    pub name: String,
    pub user: String,
    pub command: String,
    pub nb_nodes: u32,
    pub weight: u32,
    pub max_time: Time,
    pub tasks: u32,
    pub state: CampaignState,
    pub submission_time: Time,
}

/// Lifecycle of one grid task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridTaskState {
    /// Not placed anywhere; eligible for the next dispatch wave.
    Pending,
    /// Submitted to a cluster (`cluster`/`job` identify the placement; a
    /// recorded placement with `job = NULL` is the ack window — the
    /// submission may or may not have been admitted, and the reconciler
    /// resolves it by tag before the task can move anywhere else).
    Dispatched,
    /// The remote job terminated normally.
    Done,
    /// The retry budget was exhausted.
    Failed,
}

impl GridTaskState {
    pub fn as_str(self) -> &'static str {
        match self {
            GridTaskState::Pending => "Pending",
            GridTaskState::Dispatched => "Dispatched",
            GridTaskState::Done => "Done",
            GridTaskState::Failed => "Failed",
        }
    }

    pub fn parse(s: &str) -> Option<GridTaskState> {
        Some(match s {
            "Pending" => GridTaskState::Pending,
            "Dispatched" => GridTaskState::Dispatched,
            "Done" => GridTaskState::Done,
            "Failed" => GridTaskState::Failed,
            _ => return None,
        })
    }

    /// Terminal states: the task will never be dispatched again.
    pub fn is_terminal(self) -> bool {
        matches!(self, GridTaskState::Done | GridTaskState::Failed)
    }

    pub const ALL: [GridTaskState; 4] = [
        GridTaskState::Pending,
        GridTaskState::Dispatched,
        GridTaskState::Done,
        GridTaskState::Failed,
    ];
}

impl std::fmt::Display for GridTaskState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A row of the `grid_tasks` table: one task of a campaign and its
/// current (or last) remote placement.
#[derive(Debug, Clone)]
pub struct GridTask {
    pub id: u64,
    pub campaign: CampaignId,
    /// 0-based index within the campaign (the `{i}` substitution).
    pub index: u32,
    pub state: GridTaskState,
    /// Cluster the task is (or was last) placed on.
    pub cluster: Option<String>,
    /// Remote job id on `cluster`, once the submission was acknowledged.
    pub job: Option<JobId>,
    /// Dispatch attempts so far (1 after the first placement).
    pub attempts: u32,
    /// Grid-clock instant (ms) of the current placement; the reconciler
    /// cancels and re-places a task whose remote job still has not
    /// started `stale_after` past this (0 = placed before the last grid
    /// restart — the timer restarts at boot).
    pub dispatched_at: Time,
    /// Last failure/requeue reason.
    pub message: String,
}

impl GridTask {
    /// The tag appended to every dispatched command, by which a remote
    /// job is traced back to its grid task (ack-loss recovery and the
    /// rejoin orphan sweep both key on it). Keyed by the campaign's
    /// random [`Campaign::token`], not its id — ids collide across grid
    /// instances, tokens do not.
    pub fn tag(token: u64, index: u32) -> String {
        format!("#grid:{token:016x}:{index}")
    }

    /// Parse a command's grid tag back into `(campaign token, index)`.
    pub fn parse_tag(command: &str) -> Option<(u64, u32)> {
        let (_, rest) = command.rsplit_once("#grid:")?;
        let (tok, i) = rest.split_once(':')?;
        Some((
            u64::from_str_radix(tok.trim(), 16).ok()?,
            i.trim().parse().ok()?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_string_roundtrips() {
        for s in GridTaskState::ALL {
            assert_eq!(GridTaskState::parse(s.as_str()), Some(s));
        }
        assert_eq!(GridTaskState::parse("bogus"), None);
        for s in [CampaignState::Active, CampaignState::Done] {
            assert_eq!(CampaignState::parse(s.as_str()), Some(s));
        }
        assert_eq!(CampaignState::parse("bogus"), None);
    }

    #[test]
    fn terminal_states() {
        assert!(GridTaskState::Done.is_terminal());
        assert!(GridTaskState::Failed.is_terminal());
        assert!(!GridTaskState::Pending.is_terminal());
        assert!(!GridTaskState::Dispatched.is_terminal());
    }

    #[test]
    fn tag_roundtrip() {
        let cmd = format!("sleep 2 {}", GridTask::tag(0xdead_beef_0042, 42));
        assert_eq!(GridTask::parse_tag(&cmd), Some((0xdead_beef_0042, 42)));
        assert_eq!(GridTask::parse_tag("sleep 2"), None);
        assert_eq!(GridTask::parse_tag("echo #grid:zz:y"), None);
    }
}
