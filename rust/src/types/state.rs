//! The job state machine of fig. 1.
//!
//! Jobs are `Waiting` at submission, may be `Hold` on user demand, move to
//! `toLaunch` once scheduled, then walk the launch sequence
//! (`Launching` → `Running` → `Terminated`). Any abnormal termination
//! (including removal of the submission) places the job in `Error` via
//! `toError`. `toAckReservation` is the intermediate state of the
//! reservation negotiation (§2, fig. 1).


/// All states a job can be in (field `state` of the jobs table, fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    /// Submitted, not yet scheduled.
    Waiting,
    /// Held on user demand; excluded from scheduling until released.
    Hold,
    /// Scheduled; the execution module must pick it up.
    ToLaunch,
    /// Abnormal-termination path entry (cancellation, launch failure...).
    ToError,
    /// Reservation accepted by the scheduler, awaiting user acknowledgment.
    ToAckReservation,
    /// The launcher is deploying the job on its nodes.
    Launching,
    /// Executing on the nodes.
    Running,
    /// Finished normally.
    Terminated,
    /// Finished abnormally (terminal).
    Error,
}

impl JobState {
    /// Legal transitions of fig. 1. Every state-changing write to the jobs
    /// table is validated against this relation, which is what keeps the
    /// database "in a coherent state" so that module crashes are harmless
    /// (§2: robustness only depends on modules leaving coherent state).
    pub fn can_transition_to(self, next: JobState) -> bool {
        use JobState::*;
        matches!(
            (self, next),
            (Waiting, Hold)
                | (Waiting, ToLaunch)
                | (Waiting, ToError)
                | (Waiting, ToAckReservation)
                | (Hold, Waiting)
                | (Hold, ToError)
                | (ToAckReservation, Waiting)
                | (ToAckReservation, ToError)
                | (ToLaunch, Launching)
                | (ToLaunch, ToError)
                | (Launching, Running)
                | (Launching, ToError)
                | (Running, Terminated)
                | (Running, ToError)
                | (ToError, Error)
        )
    }

    /// Terminal states: no further transition is legal.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Terminated | JobState::Error)
    }

    /// States in which the job occupies (or is about to occupy) resources.
    pub fn holds_resources(self) -> bool {
        matches!(
            self,
            JobState::ToLaunch | JobState::Launching | JobState::Running
        )
    }

    /// States from which the scheduler may still place the job.
    pub fn is_schedulable(self) -> bool {
        matches!(self, JobState::Waiting)
    }

    /// Database string encoding (matches the paper's field values).
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Waiting => "Waiting",
            JobState::Hold => "Hold",
            JobState::ToLaunch => "toLaunch",
            JobState::ToError => "toError",
            JobState::ToAckReservation => "toAckReservation",
            JobState::Launching => "Launching",
            JobState::Running => "Running",
            JobState::Terminated => "Terminated",
            JobState::Error => "Error",
        }
    }

    /// Parse the database string encoding.
    pub fn parse(s: &str) -> Option<JobState> {
        Some(match s {
            "Waiting" => JobState::Waiting,
            "Hold" => JobState::Hold,
            "toLaunch" => JobState::ToLaunch,
            "toError" => JobState::ToError,
            "toAckReservation" => JobState::ToAckReservation,
            "Launching" => JobState::Launching,
            "Running" => JobState::Running,
            "Terminated" => JobState::Terminated,
            "Error" => JobState::Error,
            _ => return None,
        })
    }

    /// All states, for enumeration in tests and reports.
    pub const ALL: [JobState; 9] = [
        JobState::Waiting,
        JobState::Hold,
        JobState::ToLaunch,
        JobState::ToError,
        JobState::ToAckReservation,
        JobState::Launching,
        JobState::Running,
        JobState::Terminated,
        JobState::Error,
    ];
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Restart-reconciliation policy: what recovery does with jobs stranded
/// in-flight (`toLaunch`/`Launching`/`Running`) when the process crashed
/// — their launcher/execution threads died with it, so the database alone
/// cannot finish them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Fail the stranded job through the abnormal path (`toError` →
    /// `Error`) with a `RECOVERY_FAIL` event — conservative: the user
    /// resubmits, nothing runs twice.
    #[default]
    FailInFlight,
    /// Strip the job's execution state (assignments, start time, bpid)
    /// and requeue it as `Waiting` with a `RECOVERY_REQUEUE` event — the
    /// job runs again; appropriate for idempotent workloads.
    Requeue,
}

impl RecoveryPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            RecoveryPolicy::FailInFlight => "fail",
            RecoveryPolicy::Requeue => "requeue",
        }
    }

    pub fn parse(s: &str) -> Option<RecoveryPolicy> {
        Some(match s {
            "fail" => RecoveryPolicy::FailInFlight,
            "requeue" => RecoveryPolicy::Requeue,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_lifecycle() {
        let path = [
            JobState::Waiting,
            JobState::ToLaunch,
            JobState::Launching,
            JobState::Running,
            JobState::Terminated,
        ];
        for w in path.windows(2) {
            assert!(w[0].can_transition_to(w[1]), "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn hold_and_release() {
        assert!(JobState::Waiting.can_transition_to(JobState::Hold));
        assert!(JobState::Hold.can_transition_to(JobState::Waiting));
        // A held job cannot be launched directly.
        assert!(!JobState::Hold.can_transition_to(JobState::ToLaunch));
    }

    #[test]
    fn reservation_negotiation() {
        assert!(JobState::Waiting.can_transition_to(JobState::ToAckReservation));
        assert!(JobState::ToAckReservation.can_transition_to(JobState::Waiting));
        assert!(JobState::ToAckReservation.can_transition_to(JobState::ToError));
    }

    #[test]
    fn every_abnormal_exit_goes_through_to_error() {
        use JobState::*;
        for s in [Waiting, Hold, ToAckReservation, ToLaunch, Launching, Running] {
            assert!(s.can_transition_to(ToError), "{s} must be cancellable");
        }
        assert!(ToError.can_transition_to(Error));
    }

    #[test]
    fn terminal_states_have_no_exit() {
        for s in [JobState::Terminated, JobState::Error] {
            for next in JobState::ALL {
                assert!(!s.can_transition_to(next), "{s} -> {next} must be illegal");
            }
        }
    }

    #[test]
    fn no_transition_to_self() {
        for s in JobState::ALL {
            assert!(!s.can_transition_to(s));
        }
    }

    #[test]
    fn string_roundtrip() {
        for s in JobState::ALL {
            assert_eq!(JobState::parse(s.as_str()), Some(s));
        }
        assert_eq!(JobState::parse("bogus"), None);
    }

    #[test]
    fn resource_holding_states() {
        assert!(JobState::Running.holds_resources());
        assert!(JobState::ToLaunch.holds_resources());
        assert!(!JobState::Waiting.holds_resources());
        assert!(!JobState::Terminated.holds_resources());
    }
}
