//! Ablation A5 (DESIGN.md §6): the cost of the paper's core design choice
//! — all module communication through the database. Measures the
//! SQL-equivalent operations on the jobs path at realistic table sizes,
//! WHERE-expression evaluation throughput, and — since the query engine
//! gained secondary indexes — the probe-vs-scan gap on identical data,
//! with the planner's access-path counters printed as proof.
//!
//! Emits machine-readable results to `BENCH_db.json` at the repo root so
//! the perf trajectory is diffable across PRs, plus `BENCH_wal.json` for
//! the durability path (WAL append throughput, recovery time, and the
//! group-commit vs per-record-fsync comparison at 8 concurrent writers).

mod common;

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use common::{bench, BenchResult};
use oar::db::{Db, Expr, Value};
use oar::types::{Job, JobSpec, JobState, Node, Queue};
use oar::util::Json;

/// Populate: 64 nodes + `jobs` jobs with a realistic state mix — ~1%
/// Waiting, ~1% Running, the rest Terminated — the shape of a long-lived
/// scheduler database, where state-filtered queries are selective.
fn filled_db(jobs: usize) -> Db {
    let mut db = Db::with_standard_queues();
    for i in 1..=64u32 {
        db.add_node(
            Node::new(i, &format!("n{i}"), 2)
                .with_prop("mem", Value::Int(256 * (1 + i as i64 % 4))),
        );
    }
    for i in 0..jobs {
        let spec = JobSpec::batch(&format!("u{}", i % 10), "date", 1 + (i % 4) as u32, 600);
        let id = db.insert_job(Job::from_spec(&spec, i as i64));
        match i % 100 {
            0 => {} // stays Waiting
            1 => {
                db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
                db.set_job_state(id, JobState::Launching, 2).unwrap();
                db.set_job_state(id, JobState::Running, 3).unwrap();
            }
            _ => {
                db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
                db.set_job_state(id, JobState::Launching, 2).unwrap();
                db.set_job_state(id, JobState::Running, 3).unwrap();
                db.set_job_state(id, JobState::Terminated, 4).unwrap();
            }
        }
    }
    db
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut plans: BTreeMap<String, Json> = BTreeMap::new();
    let mut speedups: BTreeMap<String, f64> = BTreeMap::new();

    println!("== db: table ops at realistic sizes ==");
    for size in [100usize, 1000, 10_000, 100_000] {
        let mut db = filled_db(size);

        results.push(bench(&format!("insert_job/{size}_existing"), 10, 100, || {
            db.insert_job(Job::from_spec(&JobSpec::default(), 0))
        }));

        results.push(bench(&format!("set_job_state/{size}"), 0, 100, || {
            // walk a fresh job through its lifecycle each iteration
            let id = db.insert_job(Job::from_spec(&JobSpec::default(), 0));
            db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
            db.set_job_state(id, JobState::Launching, 2).unwrap();
            db.set_job_state(id, JobState::Running, 3).unwrap();
            db.set_job_state(id, JobState::Terminated, 4).unwrap();
        }));

        results.push(bench(&format!("matching_nodes_expr/{size}"), 3, 50, || {
            db.matching_nodes("mem >= 512").unwrap().len()
        }));
    }

    println!("\n== indexed vs scan (predicate pushdown) ==");
    for size in [10_000usize, 100_000] {
        let mut db = filled_db(size);

        // --- probe path (the engine's default: standard indexes on) ---
        db.reset_stats();
        let indexed = [
            bench(&format!("jobs_in_state_waiting/{size}"), 3, 50, || {
                db.jobs_in_state(JobState::Waiting).len()
            }),
            bench(&format!("waiting_in_queue_default/{size}"), 3, 50, || {
                db.waiting_jobs_in_queue("default").len()
            }),
            bench(&format!("count_waiting/{size}"), 3, 200, || {
                db.count_jobs_in_state(JobState::Waiting)
            }),
            bench(&format!("jobs_where_state_eq/{size}"), 3, 50, || {
                db.jobs_where(&Expr::parse("state = 'Waiting'").unwrap()).len()
            }),
        ];
        let s = db.stats();
        println!(
            "  plan proof ({size} rows, indexed): {} index probes, {} full scans",
            s.index_probes, s.full_scans
        );
        plans.insert(
            format!("{size}/indexed"),
            Json::obj(vec![
                ("index_probes", Json::Num(s.index_probes as f64)),
                ("full_scans", Json::Num(s.full_scans as f64)),
            ]),
        );

        // --- scan path: same data, indexes dropped ---
        db.drop_all_indexes();
        db.reset_stats();
        let scanned = [
            bench(&format!("jobs_in_state_waiting_scan/{size}"), 3, 50, || {
                db.jobs_in_state(JobState::Waiting).len()
            }),
            bench(&format!("waiting_in_queue_default_scan/{size}"), 3, 50, || {
                db.waiting_jobs_in_queue("default").len()
            }),
            bench(&format!("count_waiting_scan/{size}"), 3, 200, || {
                db.count_jobs_in_state(JobState::Waiting)
            }),
            bench(&format!("jobs_where_state_eq_scan/{size}"), 3, 50, || {
                db.jobs_where(&Expr::parse("state = 'Waiting'").unwrap()).len()
            }),
        ];
        let s = db.stats();
        println!(
            "  plan proof ({size} rows, dropped):  {} index probes, {} full scans",
            s.index_probes, s.full_scans
        );
        plans.insert(
            format!("{size}/scan"),
            Json::obj(vec![
                ("index_probes", Json::Num(s.index_probes as f64)),
                ("full_scans", Json::Num(s.full_scans as f64)),
            ]),
        );

        for (probe, scan) in indexed.iter().zip(scanned.iter()) {
            let ratio = scan.mean.as_nanos() as f64 / probe.mean.as_nanos().max(1) as f64;
            println!("  {:<44} {ratio:>8.1}x faster with index", probe.name);
            speedups.insert(probe.name.clone(), ratio);
        }
        results.extend(indexed);
        results.extend(scanned);
    }

    println!("\n== materialized views vs recompute (load/occupancy ablation) ==");
    let views_json = bench_views(&mut results, &mut speedups);

    println!("\n== expression engine ==");
    let expr = Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND switch = 'sw1'").unwrap();
    let row = {
        let n = Node::new(1, "n1", 2)
            .with_prop("mem", Value::Int(1024))
            .with_prop("cpu_mhz", Value::Int(2400))
            .with_prop("switch", Value::Text("sw1".into()));
        n.property_row()
    };
    results.push(bench("expr_parse/3_clauses", 100, 1000, || {
        Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND switch = 'sw1'").unwrap()
    }));
    results.push(bench("expr_eval/3_clauses", 100, 1000, || expr.matches(&row)));

    println!("\n== snapshot/restore (data-safety path) ==");
    let db = filled_db(1000);
    let path = std::env::temp_dir().join("oar_bench_snapshot.json");
    results.push(bench("snapshot/1000_jobs", 1, 20, || db.snapshot(&path).unwrap()));
    results.push(bench("restore/1000_jobs", 1, 20, || Db::restore(&path).unwrap()));
    let _ = std::fs::remove_file(path);

    let wal = bench_wal();
    let group = bench_group_commit();

    write_report(&results, plans, speedups, views_json);
    write_wal_report(&wal, &group);
}

/// Materialized-view ablation: the load/occupancy questions the hot
/// paths ask (`Server::load_info`, the meta-scheduler's depth probes,
/// `fleet_summary`, the grid's `load` probe), answered from the
/// incrementally-maintained views vs recomputed from the base tables on
/// identical data. `OAR_DB_VIEW_JOBS` sizes the table — 100k by default
/// so local runs stay quick; CI sets 1M, the acceptance scale at which
/// the views must win by >= 10x.
fn bench_views(results: &mut Vec<BenchResult>, speedups: &mut BTreeMap<String, f64>) -> Json {
    let jobs: usize = std::env::var("OAR_DB_VIEW_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|n| *n > 0)
        .unwrap_or(100_000);
    println!("  building the {jobs}-job table (occupancy for the ~1% running)...");
    let mut db = filled_db(jobs);
    // `filled_db` leaves its Running jobs unassigned; claim nodes for
    // them so the occupancy views and the recompute join have real work.
    for i in (1..jobs).step_by(100) {
        db.assign_nodes((i + 1) as u64, &[(i % 64) as u32 + 1], 2);
    }
    assert!(db.verify_views(), "views diverged from recompute");

    db.reset_stats();
    let pairs = [
        (
            bench(&format!("view/cluster_load/{jobs}"), 10, 100, || {
                db.cluster_load()
            }),
            bench(&format!("recompute/cluster_load/{jobs}"), 1, 10, || {
                db.cluster_load_recompute()
            }),
        ),
        (
            bench(&format!("view/node_occupancy/{jobs}"), 10, 100, || {
                db.node_occupancy().len()
            }),
            bench(&format!("recompute/node_occupancy/{jobs}"), 1, 10, || {
                db.busy_procs_by_node().len()
            }),
        ),
        (
            bench(&format!("view/queue_depth/{jobs}"), 10, 100, || {
                db.queue_depth("default")
            }),
            bench(&format!("recompute/queue_depth/{jobs}"), 1, 10, || {
                db.queue_depths_recompute().len()
            }),
        ),
        (
            bench(&format!("view/jobs_by_state/{jobs}"), 10, 100, || {
                db.state_depth(JobState::Waiting)
            }),
            bench(&format!("recompute/jobs_by_state/{jobs}"), 1, 10, || {
                db.jobs_by_state_recompute().len()
            }),
        ),
        (
            bench(&format!("view/fleet/{jobs}"), 10, 100, || {
                db.fleet_view().len()
            }),
            bench(&format!("recompute/fleet/{jobs}"), 3, 50, || {
                db.all_nodes().len()
            }),
        ),
    ];
    let s = db.stats();
    println!(
        "  plan proof: {} view hits | {} index probes | {} full scans",
        s.view_hits, s.index_probes, s.full_scans
    );

    let mut ablation = Vec::new();
    for (view, recompute) in pairs {
        let ratio =
            recompute.mean.as_nanos() as f64 / view.mean.as_nanos().max(1) as f64;
        let name = view.name.trim_start_matches("view/").to_string();
        println!("  {name:<44} {ratio:>8.1}x faster from the view");
        speedups.insert(view.name.clone(), ratio);
        ablation.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("view_mean_ns", Json::Num(view.mean.as_nanos() as f64)),
            (
                "recompute_mean_ns",
                Json::Num(recompute.mean.as_nanos() as f64),
            ),
            ("speedup", Json::Num(ratio)),
        ]));
        results.push(view);
        results.push(recompute);
    }
    Json::obj(vec![
        ("jobs", Json::Num(jobs as f64)),
        ("view_hits", Json::Num(s.view_hits as f64)),
        ("index_probes", Json::Num(s.index_probes as f64)),
        ("full_scans", Json::Num(s.full_scans as f64)),
        ("ablation", Json::Arr(ablation)),
    ])
}

/// One WAL measurement row.
struct WalPoint {
    mutations: u64,
    records: u64,
    append_secs: f64,
    replay_recover_secs: f64,
    replay_records: u64,
    snapshot_recover_secs: f64,
}

/// Durability-path benchmark: WAL append throughput and recovery time
/// (pure WAL replay vs. snapshot + empty tail) at 10k/100k mutations.
fn bench_wal() -> Vec<WalPoint> {
    println!("\n== WAL durability (append throughput, recovery time) ==");
    let mut out = Vec::new();
    for mutations in [10_000u64, 100_000] {
        let dir = std::env::temp_dir().join(format!("oar_bench_wal_{mutations}"));
        let _ = std::fs::remove_dir_all(&dir);
        let (mut db, _) = Db::recover(&dir).unwrap();
        for q in Queue::standard_set() {
            db.add_queue(q);
        }
        let base = db.wal_records();

        // Mutation mix: insert + the toLaunch/Launching/Running/Terminated
        // walk — the live jobs path, one WAL record per logical write.
        let t0 = Instant::now();
        let mut done = 0u64;
        while done < mutations {
            let id = db.insert_job(Job::from_spec(&JobSpec::default(), done as i64));
            db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
            db.set_job_state(id, JobState::Launching, 2).unwrap();
            done += 3;
        }
        let append_secs = t0.elapsed().as_secs_f64();
        let records = db.wal_records() - base;
        drop(db);

        // Recovery 1: no snapshot — the whole history replays.
        let t0 = Instant::now();
        let (mut rec, replay_stats) = Db::recover(&dir).unwrap();
        let replay_recover_secs = t0.elapsed().as_secs_f64();
        assert!(replay_stats.replayed >= records, "replay lost records");

        // Recovery 2: after a checkpoint — snapshot load + empty tail.
        rec.checkpoint().unwrap();
        drop(rec);
        let t0 = Instant::now();
        let (_rec, stats) = Db::recover(&dir).unwrap();
        let snapshot_recover_secs = t0.elapsed().as_secs_f64();
        assert_eq!(stats.replayed, 0, "tail must be empty after checkpoint");
        assert!(stats.snapshot_loaded);

        println!(
            "  {mutations:>7} mutations: append {:>10.0} rec/s, replay-recover {:>7.1} ms, snapshot-recover {:>7.1} ms",
            records as f64 / append_secs,
            replay_recover_secs * 1e3,
            snapshot_recover_secs * 1e3,
        );
        out.push(WalPoint {
            mutations,
            records,
            append_secs,
            replay_recover_secs,
            replay_records: replay_stats.replayed,
            snapshot_recover_secs,
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
    out
}

/// The group-commit comparison: same writer fleet, same sync-on-flush
/// durability, batched vs per-record fsync.
struct GroupCommitPoint {
    writers: usize,
    per_writer: u64,
    baseline_records: u64,
    baseline_secs: f64,
    group_records: u64,
    group_secs: f64,
}

impl GroupCommitPoint {
    fn baseline_rps(&self) -> f64 {
        self.baseline_records as f64 / self.baseline_secs.max(1e-12)
    }
    fn group_rps(&self) -> f64 {
        self.group_records as f64 / self.group_secs.max(1e-12)
    }
    fn speedup(&self) -> f64 {
        self.group_rps() / self.baseline_rps().max(1e-12)
    }
}

/// One writer-fleet run against a fresh durable store. `group` picks the
/// commit discipline: off = every append flushes + fsyncs inline (the
/// classic one-fsync-per-record baseline); on = appends buffer under the
/// store lock and each writer commits through a [`oar::db::WalCommit`]
/// handle *after* releasing it, so whichever committer reaches the sink
/// first fsyncs the whole batch the others just buffered. Both modes end
/// with a recovery pass proving no acknowledged record was lost.
fn run_writer_fleet(group: bool, writers: usize, per_writer: u64, tag: &str) -> (u64, f64) {
    use std::sync::{Arc, Mutex};
    let dir = std::env::temp_dir().join(format!(
        "oar_bench_wal_gc_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let (mut db, _) = Db::recover(&dir).unwrap();
    db.set_wal_sync(true);
    db.set_wal_group_commit(group);
    let base = db.wal_records();
    let commit = db.wal_commit_handle().expect("durable store has a WAL");
    let db = Arc::new(Mutex::new(db));

    let t0 = Instant::now();
    let fleet: Vec<_> = (0..writers)
        .map(|w| {
            let db = db.clone();
            let commit = commit.clone();
            std::thread::spawn(move || {
                for i in 0..per_writer {
                    {
                        let mut db = db.lock().unwrap();
                        db.insert_job(Job::from_spec(
                            &JobSpec::batch(&format!("w{w}"), "date", 1, 60),
                            i as i64,
                        ));
                    }
                    if group {
                        // Ack discipline: the write is acknowledged only
                        // after its batch is on disk — but the fsync runs
                        // outside the store lock, so the other writers
                        // keep mutating (and buffering) meanwhile.
                        commit.commit().expect("group commit");
                    }
                }
            })
        })
        .collect();
    for h in fleet {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();

    let db = Arc::try_unwrap(db)
        .ok()
        .expect("writer fleet joined")
        .into_inner()
        .unwrap();
    let records = db.wal_records() - base;
    assert_eq!(records, writers as u64 * per_writer, "lost appends");
    drop(db);
    let (_rec, stats) = Db::recover(&dir).unwrap();
    assert!(
        stats.replayed >= records,
        "recovery lost acknowledged records ({} < {records})",
        stats.replayed
    );
    let _ = std::fs::remove_dir_all(&dir);
    (records, secs)
}

/// Group-commit ablation: append throughput of 8 concurrent writers with
/// power-loss durability (fsync on flush), batched vs per-record. The
/// env knobs `OAR_WAL_WRITERS` / `OAR_WAL_PER_WRITER` resize it.
fn bench_group_commit() -> GroupCommitPoint {
    let env = |key: &str, default: u64| -> u64 {
        std::env::var(key)
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|n| *n > 0)
            .unwrap_or(default)
    };
    let writers = env("OAR_WAL_WRITERS", 8) as usize;
    let per_writer = env("OAR_WAL_PER_WRITER", 125);
    println!(
        "\n== WAL group commit ({writers} concurrent writers x {per_writer}, sync-on-flush) =="
    );
    let (baseline_records, baseline_secs) =
        run_writer_fleet(false, writers, per_writer, "base");
    let (group_records, group_secs) = run_writer_fleet(true, writers, per_writer, "group");
    let point = GroupCommitPoint {
        writers,
        per_writer,
        baseline_records,
        baseline_secs,
        group_records,
        group_secs,
    };
    println!(
        "  per-record fsync {:>10.0} rec/s | group commit {:>10.0} rec/s | {:.1}x",
        point.baseline_rps(),
        point.group_rps(),
        point.speedup(),
    );
    point
}

/// `BENCH_wal.json` at the repo root: the durability perf trajectory.
fn write_wal_report(points: &[WalPoint], group: &GroupCommitPoint) {
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_wal.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("wal".into())),
        (
            "results",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("mutations", Json::Num(p.mutations as f64)),
                            ("wal_records", Json::Num(p.records as f64)),
                            ("append_secs", Json::Num(p.append_secs)),
                            (
                                "append_records_per_sec",
                                Json::Num(p.records as f64 / p.append_secs.max(1e-12)),
                            ),
                            (
                                "recover_replay_ms",
                                Json::Num(p.replay_recover_secs * 1e3),
                            ),
                            ("replayed_records", Json::Num(p.replay_records as f64)),
                            (
                                "recover_snapshot_ms",
                                Json::Num(p.snapshot_recover_secs * 1e3),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "group_commit",
            Json::obj(vec![
                ("writers", Json::Num(group.writers as f64)),
                ("mutations_per_writer", Json::Num(group.per_writer as f64)),
                (
                    "baseline_records_per_sec",
                    Json::Num(group.baseline_rps()),
                ),
                ("group_records_per_sec", Json::Num(group.group_rps())),
                ("speedup", Json::Num(group.speedup())),
            ]),
        ),
    ]);
    match std::fs::write(&out, doc.dump()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out.display()),
    }
}

/// Machine-readable results at the repo root: the perf trajectory file.
fn write_report(
    results: &[BenchResult],
    plans: BTreeMap<String, Json>,
    speedups: BTreeMap<String, f64>,
    views: Json,
) {
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_db.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("db".into())),
        ("views", views),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::Str(r.name.clone())),
                            ("iters", Json::Num(r.iters as f64)),
                            ("mean_ns", Json::Num(r.mean.as_nanos() as f64)),
                            ("p50_ns", Json::Num(r.p50.as_nanos() as f64)),
                            ("p95_ns", Json::Num(r.p95.as_nanos() as f64)),
                            ("min_ns", Json::Num(r.min.as_nanos() as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("plans", Json::Obj(plans)),
        (
            "speedups",
            Json::Obj(
                speedups
                    .into_iter()
                    .map(|(k, v)| (k, Json::Num(v)))
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(&out, doc.dump()) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
