//! Ablation A5 (DESIGN.md §6): the cost of the paper's core design choice
//! — all module communication through the database. Measures the
//! SQL-equivalent operations on the jobs path at realistic table sizes,
//! WHERE-expression evaluation throughput, and — since the query engine
//! gained secondary indexes — the probe-vs-scan gap on identical data,
//! with the planner's access-path counters printed as proof.
//!
//! Emits machine-readable results to `BENCH_db.json` at the repo root so
//! the perf trajectory is diffable across PRs.

mod common;

use std::collections::BTreeMap;
use std::path::Path;

use common::{bench, BenchResult};
use oar::db::{Db, Expr, Value};
use oar::types::{Job, JobSpec, JobState, Node};
use oar::util::Json;

/// Populate: 64 nodes + `jobs` jobs with a realistic state mix — ~1%
/// Waiting, ~1% Running, the rest Terminated — the shape of a long-lived
/// scheduler database, where state-filtered queries are selective.
fn filled_db(jobs: usize) -> Db {
    let mut db = Db::with_standard_queues();
    for i in 1..=64u32 {
        db.add_node(
            Node::new(i, &format!("n{i}"), 2)
                .with_prop("mem", Value::Int(256 * (1 + i as i64 % 4))),
        );
    }
    for i in 0..jobs {
        let spec = JobSpec::batch(&format!("u{}", i % 10), "date", 1 + (i % 4) as u32, 600);
        let id = db.insert_job(Job::from_spec(&spec, i as i64));
        match i % 100 {
            0 => {} // stays Waiting
            1 => {
                db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
                db.set_job_state(id, JobState::Launching, 2).unwrap();
                db.set_job_state(id, JobState::Running, 3).unwrap();
            }
            _ => {
                db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
                db.set_job_state(id, JobState::Launching, 2).unwrap();
                db.set_job_state(id, JobState::Running, 3).unwrap();
                db.set_job_state(id, JobState::Terminated, 4).unwrap();
            }
        }
    }
    db
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut plans: BTreeMap<String, Json> = BTreeMap::new();
    let mut speedups: BTreeMap<String, f64> = BTreeMap::new();

    println!("== db: table ops at realistic sizes ==");
    for size in [100usize, 1000, 10_000, 100_000] {
        let mut db = filled_db(size);

        results.push(bench(&format!("insert_job/{size}_existing"), 10, 100, || {
            db.insert_job(Job::from_spec(&JobSpec::default(), 0))
        }));

        results.push(bench(&format!("set_job_state/{size}"), 0, 100, || {
            // walk a fresh job through its lifecycle each iteration
            let id = db.insert_job(Job::from_spec(&JobSpec::default(), 0));
            db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
            db.set_job_state(id, JobState::Launching, 2).unwrap();
            db.set_job_state(id, JobState::Running, 3).unwrap();
            db.set_job_state(id, JobState::Terminated, 4).unwrap();
        }));

        results.push(bench(&format!("matching_nodes_expr/{size}"), 3, 50, || {
            db.matching_nodes("mem >= 512").unwrap().len()
        }));
    }

    println!("\n== indexed vs scan (predicate pushdown) ==");
    for size in [10_000usize, 100_000] {
        let mut db = filled_db(size);

        // --- probe path (the engine's default: standard indexes on) ---
        db.reset_stats();
        let indexed = [
            bench(&format!("jobs_in_state_waiting/{size}"), 3, 50, || {
                db.jobs_in_state(JobState::Waiting).len()
            }),
            bench(&format!("waiting_in_queue_default/{size}"), 3, 50, || {
                db.waiting_jobs_in_queue("default").len()
            }),
            bench(&format!("count_waiting/{size}"), 3, 200, || {
                db.count_jobs_in_state(JobState::Waiting)
            }),
            bench(&format!("jobs_where_state_eq/{size}"), 3, 50, || {
                db.jobs_where(&Expr::parse("state = 'Waiting'").unwrap()).len()
            }),
        ];
        let s = db.stats();
        println!(
            "  plan proof ({size} rows, indexed): {} index probes, {} full scans",
            s.index_probes, s.full_scans
        );
        plans.insert(
            format!("{size}/indexed"),
            Json::obj(vec![
                ("index_probes", Json::Num(s.index_probes as f64)),
                ("full_scans", Json::Num(s.full_scans as f64)),
            ]),
        );

        // --- scan path: same data, indexes dropped ---
        db.drop_all_indexes();
        db.reset_stats();
        let scanned = [
            bench(&format!("jobs_in_state_waiting_scan/{size}"), 3, 50, || {
                db.jobs_in_state(JobState::Waiting).len()
            }),
            bench(&format!("waiting_in_queue_default_scan/{size}"), 3, 50, || {
                db.waiting_jobs_in_queue("default").len()
            }),
            bench(&format!("count_waiting_scan/{size}"), 3, 200, || {
                db.count_jobs_in_state(JobState::Waiting)
            }),
            bench(&format!("jobs_where_state_eq_scan/{size}"), 3, 50, || {
                db.jobs_where(&Expr::parse("state = 'Waiting'").unwrap()).len()
            }),
        ];
        let s = db.stats();
        println!(
            "  plan proof ({size} rows, dropped):  {} index probes, {} full scans",
            s.index_probes, s.full_scans
        );
        plans.insert(
            format!("{size}/scan"),
            Json::obj(vec![
                ("index_probes", Json::Num(s.index_probes as f64)),
                ("full_scans", Json::Num(s.full_scans as f64)),
            ]),
        );

        for (probe, scan) in indexed.iter().zip(scanned.iter()) {
            let ratio = scan.mean.as_nanos() as f64 / probe.mean.as_nanos().max(1) as f64;
            println!("  {:<44} {ratio:>8.1}x faster with index", probe.name);
            speedups.insert(probe.name.clone(), ratio);
        }
        results.extend(indexed);
        results.extend(scanned);
    }

    println!("\n== expression engine ==");
    let expr = Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND switch = 'sw1'").unwrap();
    let row = {
        let n = Node::new(1, "n1", 2)
            .with_prop("mem", Value::Int(1024))
            .with_prop("cpu_mhz", Value::Int(2400))
            .with_prop("switch", Value::Text("sw1".into()));
        n.property_row()
    };
    results.push(bench("expr_parse/3_clauses", 100, 1000, || {
        Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND switch = 'sw1'").unwrap()
    }));
    results.push(bench("expr_eval/3_clauses", 100, 1000, || expr.matches(&row)));

    println!("\n== snapshot/restore (data-safety path) ==");
    let db = filled_db(1000);
    let path = std::env::temp_dir().join("oar_bench_snapshot.json");
    results.push(bench("snapshot/1000_jobs", 1, 20, || db.snapshot(&path).unwrap()));
    results.push(bench("restore/1000_jobs", 1, 20, || Db::restore(&path).unwrap()));
    let _ = std::fs::remove_file(path);

    write_report(&results, plans, speedups);
}

/// Machine-readable results at the repo root: the perf trajectory file.
fn write_report(
    results: &[BenchResult],
    plans: BTreeMap<String, Json>,
    speedups: BTreeMap<String, f64>,
) {
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_db.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("db".into())),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::Str(r.name.clone())),
                            ("iters", Json::Num(r.iters as f64)),
                            ("mean_ns", Json::Num(r.mean.as_nanos() as f64)),
                            ("p50_ns", Json::Num(r.p50.as_nanos() as f64)),
                            ("p95_ns", Json::Num(r.p95.as_nanos() as f64)),
                            ("min_ns", Json::Num(r.min.as_nanos() as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("plans", Json::Obj(plans)),
        (
            "speedups",
            Json::Obj(
                speedups
                    .into_iter()
                    .map(|(k, v)| (k, Json::Num(v)))
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(&out, doc.dump()) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
