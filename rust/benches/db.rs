//! Ablation A5 (DESIGN.md §6): the cost of the paper's core design choice
//! — all module communication through the database. Measures the
//! SQL-equivalent operations on the jobs path at realistic table sizes,
//! plus WHERE-expression evaluation throughput.

mod common;

use common::bench;
use oar::db::{Db, Expr};
use oar::types::{Job, JobSpec, JobState, Node};

fn filled_db(jobs: usize) -> Db {
    let mut db = Db::with_standard_queues();
    for i in 1..=64u32 {
        db.add_node(
            Node::new(i, &format!("n{i}"), 2)
                .with_prop("mem", oar::db::Value::Int(256 * (1 + i as i64 % 4))),
        );
    }
    for i in 0..jobs {
        let spec = JobSpec::batch(&format!("u{}", i % 10), "date", 1 + (i % 4) as u32, 600);
        db.insert_job(Job::from_spec(&spec, i as i64));
    }
    db
}

fn main() {
    println!("== db: table ops at realistic sizes ==");
    for size in [100usize, 1000, 10_000] {
        let mut db = filled_db(size);

        bench(&format!("insert_job/{size}_existing"), 10, 100, || {
            db.insert_job(Job::from_spec(&JobSpec::default(), 0))
        });

        bench(&format!("jobs_in_state_waiting/{size}"), 3, 50, || {
            db.jobs_in_state(JobState::Waiting).len()
        });

        bench(&format!("set_job_state/{size}"), 0, 100, || {
            // walk a fresh job through its lifecycle each iteration
            let id = db.insert_job(Job::from_spec(&JobSpec::default(), 0));
            db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
            db.set_job_state(id, JobState::Launching, 2).unwrap();
            db.set_job_state(id, JobState::Running, 3).unwrap();
            db.set_job_state(id, JobState::Terminated, 4).unwrap();
        });

        bench(&format!("matching_nodes_expr/{size}"), 3, 50, || {
            db.matching_nodes("mem >= 512").unwrap().len()
        });
    }

    println!("\n== expression engine ==");
    let expr = Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND switch = 'sw1'").unwrap();
    let row = {
        let n = Node::new(1, "n1", 2)
            .with_prop("mem", oar::db::Value::Int(1024))
            .with_prop("cpu_mhz", oar::db::Value::Int(2400))
            .with_prop("switch", oar::db::Value::Text("sw1".into()));
        n.property_row()
    };
    bench("expr_parse/3_clauses", 100, 1000, || {
        Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND switch = 'sw1'").unwrap()
    });
    bench("expr_eval/3_clauses", 100, 1000, || expr.matches(&row));

    println!("\n== snapshot/restore (data-safety path) ==");
    let db = filled_db(1000);
    let path = std::env::temp_dir().join("oar_bench_snapshot.json");
    bench("snapshot/1000_jobs", 1, 20, || db.snapshot(&path).unwrap());
    bench("restore/1000_jobs", 1, 20, || Db::restore(&path).unwrap());
    let _ = std::fs::remove_file(path);
}
