//! End-to-end ESP2 bench (Table 3 / figs 4–8 generator, E3): full 230-job
//! simulated run per scheduler, on the Xeon shape (34 procs). Also sweeps
//! submission-order seeds to show the Table 3 ordering is not a
//! one-seed artifact.

mod common;

use common::bench;
use oar::bench::esp::{esp_workload_seeded, table3_schedulers, XEON_PROCS};
use oar::sim::{simulate, SimConfig};
use oar::types::NodeId;

fn main() {
    println!("== esp: full 230-job simulated runs (34 procs) ==");
    let nodes: Vec<(NodeId, u32)> = (1..=XEON_PROCS).map(|i| (i, 1)).collect();

    for (name, policy) in table3_schedulers() {
        let jobs = esp_workload_seeded(XEON_PROCS, 2005);
        bench(&format!("esp_full_run/{name}"), 1, 10, || {
            simulate(policy.as_ref(), &nodes, &jobs, SimConfig::default()).elapsed()
        });
    }

    println!("\n== seed sweep: efficiency ordering across submission orders ==");
    let mut oar_beats_sge = 0;
    let mut sjf_recovers = 0;
    const SEEDS: u64 = 10;
    for seed in 0..SEEDS {
        let jobs = esp_workload_seeded(XEON_PROCS, 3000 + seed);
        let effs: Vec<(String, f64)> = table3_schedulers()
            .into_iter()
            .map(|(name, policy)| {
                let r = simulate(policy.as_ref(), &nodes, &jobs, SimConfig::default());
                (name.to_string(), r.efficiency())
            })
            .collect();
        let get = |n: &str| effs.iter().find(|(name, _)| name == n).unwrap().1;
        if get("OAR") < get("SGE") {
            oar_beats_sge += 1;
        }
        if get("OAR(2)") >= get("OAR") {
            sjf_recovers += 1;
        }
        println!(
            "seed {seed}: SGE={:.4} TORQUE={:.4} MAUI={:.4} OAR={:.4} OAR(2)={:.4}",
            get("SGE"),
            get("TORQUE"),
            get("TORQUE+MAUI"),
            get("OAR"),
            get("OAR(2)")
        );
    }
    println!(
        "\nOAR < SGE on {oar_beats_sge}/{SEEDS} seeds; OAR(2) >= OAR on {sjf_recovers}/{SEEDS} seeds"
    );
}
