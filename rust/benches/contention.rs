//! Lock-contention bench for the reader-writer core: N reader threads
//! hammer the read-only RPC surface (`stat` under a state filter, plus a
//! `load` probe) while one mutator thread keeps the write path — and
//! therefore the central automaton's scheduling rounds — continuously
//! busy. Sweeps the reader count and emits `BENCH_lock.json` at the repo
//! root: p50/p99 `stat` latency and aggregate read throughput per point,
//! plus the throughput scaling ratio across the sweep. Under the old
//! global `Mutex<Db>` every reader queued behind the scheduler; under the
//! `RwLock` core read throughput should scale with readers until memory
//! bandwidth, not the lock, is the limit.
//!
//! Knobs: `OAR_LOCK_READERS` (comma list, default `1,4,16,64,256`),
//! `OAR_LOCK_MS` (measurement window per point, default 400).
//!
//! The run doubles as a correctness gate: every acknowledged submission
//! must exist exactly once in the final table, no read may error, and the
//! workload must drain to terminal states; it exits non-zero otherwise.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use oar::cluster::VirtualCluster;
use oar::server::{Server, ServerConfig};
use oar::types::{JobSpec, JobState};
use oar::util::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_list(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|n| *n > 0)
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

/// Percentile over sorted latency samples.
fn pct(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    sorted[(((sorted.len() - 1) as f64) * q).round() as usize]
}

/// One sweep point: `readers` threads for `window`, against a fresh
/// server whose mutator submits continuously. Returns the point's JSON
/// plus `(reads_per_sec, gate_ok)`.
fn run_point(readers: usize, window: Duration) -> (Json, f64, bool) {
    let cluster = Arc::new(VirtualCluster::xeon());
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));

    let stop = Arc::new(AtomicBool::new(false));
    let submitted = Arc::new(AtomicU64::new(0));

    // The mutator: a steady submission stream. With instant modeled
    // runtimes each job walks Waiting → … → Terminated within a couple
    // of automaton rounds, so the write lock is taken continuously by
    // the scheduler, the launcher bookkeeping and the submissions.
    let mutator = {
        let server = server.clone();
        let stop = stop.clone();
        let submitted = submitted.clone();
        std::thread::spawn(move || {
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let spec = JobSpec::batch("contender", "date", 1 + (i % 2) as u32, 60);
                if let Ok(Ok(_)) = server.submit(&spec) {
                    submitted.fetch_add(1, Ordering::Relaxed);
                }
                i += 1;
                if i % 64 == 0 {
                    // Let the automaton drain: the point is a *mutating*
                    // scheduler, not an unbounded backlog.
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        })
    };

    // Warm the table a little so the first reads see real rows.
    std::thread::sleep(Duration::from_millis(20));

    let t0 = Instant::now();
    let workers: Vec<_> = (0..readers)
        .map(|r| {
            let server = server.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut lats: Vec<Duration> = Vec::with_capacity(4096);
                let mut errors = 0u64;
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let t = Instant::now();
                    match server.stat(Some("state = 'Waiting'")) {
                        Ok(_) => lats.push(t.elapsed()),
                        Err(_) => errors += 1,
                    }
                    // Mix in the other read-only verbs so the point
                    // exercises the whole snapshot surface, unmeasured.
                    match i % 16 {
                        3 => {
                            let _ = server.load_info();
                        }
                        7 => {
                            let _ = server.queues();
                        }
                        11 if r == 0 => {
                            let _ = server.nodes();
                        }
                        _ => {}
                    }
                    i += 1;
                }
                (lats, errors)
            })
        })
        .collect();

    std::thread::sleep(window);
    stop.store(true, Ordering::Relaxed);

    let mut lats: Vec<Duration> = Vec::new();
    let mut read_errors = 0u64;
    for w in workers {
        let (l, e) = w.join().expect("reader thread");
        lats.extend(l);
        read_errors += e;
    }
    let wall = t0.elapsed();
    mutator.join().expect("mutator thread");

    let submitted = submitted.load(Ordering::Relaxed) as usize;
    let drained = server.wait_all_terminal(Duration::from_secs(120));
    let db_jobs = server.read_db(|db| db.job_count());
    let stranded = server.read_db(|db| {
        JobState::ALL
            .iter()
            .filter(|s| !s.is_terminal())
            .map(|s| db.count_jobs_in_state(*s))
            .sum::<usize>()
    });
    let ok = drained && read_errors == 0 && db_jobs == submitted && stranded == 0;

    lats.sort_unstable();
    let reads = lats.len();
    let mean_us =
        lats.iter().map(|d| d.as_micros() as f64).sum::<f64>() / reads.max(1) as f64;
    let p50 = pct(&lats, 0.50);
    let p99 = pct(&lats, 0.99);
    let max = lats.last().copied().unwrap_or(Duration::ZERO);
    let reads_per_sec = reads as f64 / wall.as_secs_f64().max(1e-9);
    let subs_per_sec = submitted as f64 / wall.as_secs_f64().max(1e-9);

    println!(
        "  {readers:>4} readers: {reads_per_sec:>9.0} reads/s  stat p50={p50:?} p99={p99:?} max={max:?}  \
         (writer {subs_per_sec:.0} subs/s, {} jobs, drain {}, errors {read_errors})",
        db_jobs,
        if ok { "ok" } else { "FAILED" },
    );

    let point = Json::obj(vec![
        ("readers", Json::Num(readers as f64)),
        ("reads", Json::Num(reads as f64)),
        ("reads_per_sec", Json::Num(reads_per_sec)),
        (
            "stat_latency_us",
            Json::obj(vec![
                ("mean", Json::Num(mean_us)),
                ("p50", Json::Num(p50.as_micros() as f64)),
                ("p99", Json::Num(p99.as_micros() as f64)),
                ("max", Json::Num(max.as_micros() as f64)),
            ]),
        ),
        ("writer_submissions", Json::Num(submitted as f64)),
        ("writer_submissions_per_sec", Json::Num(subs_per_sec)),
        (
            "verified",
            Json::obj(vec![
                ("drained", Json::Bool(drained)),
                ("read_errors", Json::Num(read_errors as f64)),
                ("db_jobs", Json::Num(db_jobs as f64)),
                ("stranded", Json::Num(stranded as f64)),
            ]),
        ),
    ]);
    (point, reads_per_sec, ok)
}

fn main() {
    let sweep = env_list("OAR_LOCK_READERS", &[1, 4, 16, 64, 256]);
    let window = Duration::from_millis(env_usize("OAR_LOCK_MS", 400) as u64);
    println!(
        "== contention: reader sweep {sweep:?} x {window:?} under a continuously mutating scheduler ==\n"
    );

    let mut points = Vec::new();
    let mut throughputs = Vec::new();
    let mut all_ok = true;
    for readers in &sweep {
        let (point, tp, ok) = run_point(*readers, window);
        points.push(point);
        throughputs.push(tp);
        all_ok &= ok;
    }

    // Scaling ratio: aggregate read throughput at the widest point vs a
    // single reader. Under the old global mutex this hovered near 1.0
    // (every reader serialized); the RwLock core should grow it with the
    // reader count until cores run out.
    let base = throughputs.first().copied().unwrap_or(0.0).max(1e-9);
    let peak = throughputs.iter().copied().fold(0.0f64, f64::max);
    let scaling = peak / base;
    println!("\nread-throughput scaling (peak/1-reader): {scaling:.2}x");

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_lock.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("lock".into())),
        ("window_ms", Json::Num(window.as_millis() as f64)),
        ("sweep", Json::Arr(points)),
        ("read_throughput_scaling", Json::Num(scaling)),
    ]);
    std::fs::write(&out, doc.dump()).expect("write BENCH_lock.json");
    println!("wrote {}", out.display());

    if !all_ok {
        eprintln!("CONTENTION VERIFICATION FAILED");
        std::process::exit(1);
    }
}
