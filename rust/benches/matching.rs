//! Ablation A1 (DESIGN.md §6): resource matching — SQL row-at-a-time vs
//! dense Rust reference vs AOT HLO kernel through PJRT, at the scheduling
//! round's batch shapes. This is the L1/L3 hot-path microbenchmark.

mod common;

use common::bench;
use oar::cluster::VirtualCluster;
use oar::matching::encode::{Encoder, JobToMatch};
use oar::matching::{ReferenceStep, ScheduleStep, SqlMatcher};
use oar::runtime::HloStep;

fn jobs(n: usize) -> Vec<JobToMatch> {
    (0..n)
        .map(|i| JobToMatch {
            id: i as u64 + 1,
            properties: match i % 4 {
                0 => String::new(),
                1 => "mem >= 256".into(),
                2 => "mem >= 256 AND cpu_mhz >= 733".into(),
                _ => "switch = 'sw2'".into(),
            },
            total_procs: 1 + (i % 4) as u32,
            duration: 600,
            wait_time: i as i64,
            queue_priority: 10,
            best_effort: false,
        })
        .collect()
}

fn main() {
    println!("== matching: SQL vs dense-reference vs HLO/PJRT ==");
    let cluster = VirtualCluster::icluster();
    let nodes = cluster.nodes().to_vec();
    let encoder = Encoder::from_nodes(&nodes);
    let free = vec![vec![1.0f32; oar::matching::T]; nodes.len()];

    for batch in [8usize, 32, 64] {
        let js = jobs(batch);

        bench(&format!("sql_match/{batch}jobs_119nodes"), 3, 30, || {
            js.iter()
                .map(|j| SqlMatcher::eligible_nodes(&j.properties, &nodes).unwrap().len())
                .sum::<usize>()
        });

        bench(&format!("encode/{batch}jobs_119nodes"), 3, 30, || {
            encoder.encode(&js, &nodes, &free, 300, [0.0; oar::matching::F])
        });

        let batch_enc = encoder.encode(&js, &nodes, &free, 300, [0.0; oar::matching::F]);
        let mut reference = ReferenceStep;
        bench(&format!("dense_reference/{batch}jobs_119nodes"), 3, 30, || {
            reference.run(&batch_enc.input).unwrap()
        });

        match HloStep::load_default() {
            Ok(mut hlo) => {
                bench(&format!("hlo_pjrt/{batch}jobs_119nodes"), 3, 30, || {
                    hlo.run(&batch_enc.input).unwrap()
                });
            }
            Err(_) => println!("hlo_pjrt/{batch}: SKIPPED (run `make artifacts`)"),
        }
    }
}
