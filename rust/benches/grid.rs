//! Grid federation bench: a bag-of-tasks campaign farmed over three
//! asymmetric loopback clusters, measuring tasks/sec, time-to-drain and
//! dispatch fairness (per-cluster completion share vs. capacity share,
//! summarized as Jain's fairness index over share ratios). Emits
//! `BENCH_grid.json` at the repo root alongside the DB/WAL/RPC benches.
//!
//! Knobs: `OAR_GRID_TASKS` (default 400), `OAR_GRID_SLEEP` (simulated
//! task seconds, default 2 — 40 ms at the harness scale of 0.02).
//!
//! The run doubles as a correctness gate: every task must drain `Done`
//! with a recorded placement, each cluster's terminated tagged jobs must
//! equal the grid's mapping (zero lost, zero duplicated), and the bench
//! exits non-zero otherwise.

use std::path::Path;
use std::time::{Duration, Instant};

use oar::grid::{Grid, GridConfig, TestGrid};
use oar::types::{CampaignSpec, GridTaskState, JobState};
use oar::util::Json;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let tasks = env_u64("OAR_GRID_TASKS", 400).clamp(1, 100_000) as u32;
    let sleep_s = env_u64("OAR_GRID_SLEEP", 2);
    // 8 + 4 + 2 processors: capacity shares 4/7, 2/7, 1/7.
    let shapes: &[(u32, u32)] = &[(4, 2), (2, 2), (1, 2)];
    println!("== grid: {tasks} tasks (sleep {sleep_s}) over 3 asymmetric clusters ==\n");

    let fleet = TestGrid::start(shapes, 0.02).expect("boot fleet");
    let grid = Grid::start(GridConfig::fast(fleet.cluster_configs(16))).expect("boot grid");

    let t0 = Instant::now();
    let id = grid
        .submit_campaign(&CampaignSpec::bag(
            "bench",
            "grid",
            &format!("sleep {sleep_s}"),
            tasks,
        ))
        .expect("submit campaign");
    let drained = grid.wait_campaign_drained(id, Duration::from_secs(600));
    let drain = t0.elapsed();

    let p = grid.campaign_progress(id).expect("progress");
    let counters = grid.counters();
    let statuses = grid.clusters();

    // Correctness gate: zero lost, zero duplicated, zero stranded.
    let task_rows = grid.tasks(id);
    let all_done = task_rows.iter().all(|t| t.state == GridTaskState::Done);
    let mut mapped = vec![0usize; shapes.len()];
    for t in &task_rows {
        if let Some(c) = t.cluster.as_deref().and_then(|c| c.strip_prefix('c')) {
            if let Ok(i) = c.parse::<usize>() {
                mapped[i] += 1;
            }
        }
    }
    let mut duplicated = 0usize;
    let mut lost = 0usize;
    for i in 0..shapes.len() {
        let remote = fleet.tagged_jobs_in_state(i, JobState::Terminated);
        duplicated += remote.saturating_sub(mapped[i]);
        lost += mapped[i].saturating_sub(remote);
    }
    let ok = drained && all_done && p.done == tasks && p.failed == 0 && duplicated == 0 && lost == 0;

    // Fairness: completion share / capacity share per cluster, folded
    // into Jain's index ((Σx)² / (n·Σx²); 1.0 = perfectly proportional).
    let capacity: Vec<f64> = shapes.iter().map(|(n, p)| (n * p) as f64).collect();
    let cap_total: f64 = capacity.iter().sum();
    let ratios: Vec<f64> = (0..shapes.len())
        .map(|i| (mapped[i] as f64 / tasks as f64) / (capacity[i] / cap_total))
        .collect();
    let jain = {
        let sum: f64 = ratios.iter().sum();
        let sq: f64 = ratios.iter().map(|r| r * r).sum();
        (sum * sum) / (ratios.len() as f64 * sq).max(1e-12)
    };
    let tasks_per_sec = tasks as f64 / drain.as_secs_f64().max(1e-9);

    println!("tasks                  {tasks} ({} done, {} failed)", p.done, p.failed);
    println!("time to drain          {drain:?}");
    println!("tasks/sec              {tasks_per_sec:.1}");
    println!("dispatch fairness      jain={jain:.3} (share/capacity ratios {ratios:?})");
    println!("verified               lost={lost} duplicated={duplicated}");
    println!(
        "counters               dispatched={} retried={} orphaned={} transport_errors={} rounds={}",
        counters.dispatched,
        counters.retried,
        counters.orphaned,
        counters.transport_errors,
        counters.rounds
    );
    for (i, s) in statuses.iter().enumerate() {
        println!(
            "  {}  procs={}  completed={}  ({:.1}% vs capacity {:.1}%)",
            s.name,
            capacity[i],
            s.completed_total,
            100.0 * mapped[i] as f64 / tasks as f64,
            100.0 * capacity[i] / cap_total
        );
    }

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_grid.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("grid".into())),
        ("tasks", Json::Num(tasks as f64)),
        ("clusters", Json::Num(shapes.len() as f64)),
        ("tasks_per_sec", Json::Num(tasks_per_sec)),
        ("drain_ms", Json::Num(drain.as_millis() as f64)),
        ("fairness_jain", Json::Num(jain)),
        (
            "per_cluster",
            Json::Arr(
                (0..shapes.len())
                    .map(|i| {
                        Json::obj(vec![
                            ("name", Json::Str(format!("c{i}"))),
                            ("procs", Json::Num(capacity[i])),
                            ("completed", Json::Num(mapped[i] as f64)),
                            ("share_vs_capacity", Json::Num(ratios[i])),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "verified",
            Json::obj(vec![
                ("lost", Json::Num(lost as f64)),
                ("duplicated", Json::Num(duplicated as f64)),
                ("failed", Json::Num(p.failed as f64)),
                ("drained", Json::Bool(drained)),
            ]),
        ),
        ("dispatched", Json::Num(counters.dispatched as f64)),
        ("retried", Json::Num(counters.retried as f64)),
        ("rounds", Json::Num(counters.rounds as f64)),
    ]);
    std::fs::write(&out, doc.dump()).expect("write BENCH_grid.json");
    println!("\nwrote {}", out.display());

    let _ = grid.shutdown();
    if !ok {
        eprintln!("GRID FEDERATION VERIFICATION FAILED");
        std::process::exit(1);
    }
}
