//! RPC front-end load generator: N concurrent clients drive a loopback
//! threaded server with submissions (plus a status sweep), measuring
//! client-observed end-to-end latency (frame out → ack in) and aggregate
//! submission throughput. Emits `BENCH_rpc.json` at the repo root so the
//! fleet's perf trajectory gains a client-facing number alongside the DB
//! (`BENCH_db.json`) and WAL (`BENCH_wal.json`) benches.
//!
//! Knobs: `OAR_RPC_CLIENTS` (default 8) × `OAR_RPC_SUBS` (default 200).
//! The run doubles as a correctness gate: it verifies zero lost and zero
//! duplicated jobs (DB job multiset == acknowledged ids) and that the
//! workload drains to terminal states, and exits non-zero otherwise.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use oar::cluster::VirtualCluster;
use oar::rpc::{RpcClient, RpcConfig, RpcServer};
use oar::server::{Server, ServerConfig};
use oar::types::{JobId, JobSpec};
use oar::util::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Percentile over sorted latency samples.
fn pct(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    sorted[(((sorted.len() - 1) as f64) * q).round() as usize]
}

fn main() {
    let clients = env_usize("OAR_RPC_CLIENTS", 8).max(1);
    let per = env_usize("OAR_RPC_SUBS", 200).max(1);
    println!(
        "== rpc: {clients} concurrent clients x {per} submissions over loopback ==\n"
    );

    // The paper's Xeon testbed shape (17 bi-proc nodes), instantaneous
    // modeled latencies: the bench measures the front-end + automaton
    // path, not simulated runtimes.
    let cluster = Arc::new(VirtualCluster::xeon());
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));
    let rpc = RpcServer::start(
        server.clone(),
        RpcConfig {
            workers: clients.max(8),
            queue_depth: (2 * clients).max(16),
            ..RpcConfig::loopback()
        },
    )
    .expect("start rpc front-end");
    let addr = rpc.addr().to_string();

    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).expect("connect");
                let mut ids: Vec<JobId> = Vec::with_capacity(per);
                let mut lats: Vec<Duration> = Vec::with_capacity(per);
                for i in 0..per {
                    let spec = JobSpec::batch(
                        &format!("load-u{c}"),
                        "date",
                        1 + (i % 2) as u32,
                        60,
                    );
                    let t = Instant::now();
                    let id = client
                        .sub(&spec)
                        .expect("transport")
                        .expect("admission");
                    lats.push(t.elapsed());
                    ids.push(id);
                }
                (ids, lats)
            })
        })
        .collect();

    let mut all_ids: Vec<JobId> = Vec::with_capacity(clients * per);
    let mut lats: Vec<Duration> = Vec::with_capacity(clients * per);
    for w in workers {
        let (ids, l) = w.join().expect("client thread");
        all_ids.extend(ids);
        lats.extend(l);
    }
    let submit_wall = t0.elapsed();

    // One full status sweep under the freshly loaded table.
    let mut client = RpcClient::connect(&addr).expect("connect");
    let t = Instant::now();
    let seen = client.stat(None).expect("transport").expect("stat").len();
    let stat_lat = t.elapsed();

    let drained = server.wait_all_terminal(Duration::from_secs(300));
    let drain_wall = t0.elapsed();
    let (conns, reqs) = rpc.stats();
    rpc.drain();

    // Correctness gate: zero lost, zero duplicated.
    let total = clients * per;
    let mut unique = all_ids.clone();
    unique.sort_unstable();
    unique.dedup();
    let duplicated = total - unique.len();
    let db_jobs = server.with_db(|db| db.job_count());
    let lost = total.saturating_sub(db_jobs);
    let stranded = server.with_db(|db| {
        oar::types::JobState::ALL
            .iter()
            .filter(|s| !s.is_terminal())
            .map(|s| db.count_jobs_in_state(*s))
            .sum::<usize>()
    });
    let ok = drained
        && duplicated == 0
        && lost == 0
        && db_jobs == total
        && stranded == 0
        && seen == total;

    lats.sort_unstable();
    let mean_us =
        lats.iter().map(|d| d.as_micros() as f64).sum::<f64>() / lats.len().max(1) as f64;
    let p50 = pct(&lats, 0.50);
    let p99 = pct(&lats, 0.99);
    let max = lats.last().copied().unwrap_or(Duration::ZERO);
    let throughput = total as f64 / submit_wall.as_secs_f64().max(1e-9);

    println!("submissions            {total} ({clients} clients x {per})");
    println!("acknowledged unique    {}", unique.len());
    println!("db jobs                {db_jobs} (lost={lost} duplicated={duplicated})");
    println!("submissions/sec        {throughput:.0}");
    println!(
        "e2e latency            mean={mean_us:.0}us p50={p50:?} p99={p99:?} max={max:?}"
    );
    println!("stat full-table sweep  {stat_lat:?} ({seen} rows)");
    println!(
        "drain to terminal      {} in {drain_wall:?} (stranded={stranded})",
        if drained { "ok" } else { "TIMEOUT" }
    );
    println!("front-end              {conns} connections, {reqs} requests served");

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_rpc.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("rpc".into())),
        ("clients", Json::Num(clients as f64)),
        ("submissions_per_client", Json::Num(per as f64)),
        ("total_submissions", Json::Num(total as f64)),
        ("submissions_per_sec", Json::Num(throughput)),
        (
            "latency_us",
            Json::obj(vec![
                ("mean", Json::Num(mean_us)),
                ("p50", Json::Num(p50.as_micros() as f64)),
                ("p99", Json::Num(p99.as_micros() as f64)),
                ("max", Json::Num(max.as_micros() as f64)),
            ]),
        ),
        ("stat_full_table_us", Json::Num(stat_lat.as_micros() as f64)),
        ("submit_wall_ms", Json::Num(submit_wall.as_millis() as f64)),
        ("drain_wall_ms", Json::Num(drain_wall.as_millis() as f64)),
        (
            "verified",
            Json::obj(vec![
                ("lost", Json::Num(lost as f64)),
                ("duplicated", Json::Num(duplicated as f64)),
                ("stranded", Json::Num(stranded as f64)),
                ("drained", Json::Bool(drained)),
            ]),
        ),
        ("requests_served", Json::Num(reqs as f64)),
    ]);
    std::fs::write(&out, doc.dump()).expect("write BENCH_rpc.json");
    println!("\nwrote {}", out.display());

    if !ok {
        eprintln!("RPC LOAD VERIFICATION FAILED");
        std::process::exit(1);
    }
}
