//! Tiny self-contained bench harness (criterion is unavailable offline):
//! warmup + timed iterations + summary stats, printed in a stable format
//! and appended to `results/bench.csv`.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

/// Run `f` repeatedly: `warmup` unmeasured + `iters` measured.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters.max(1);
    let pct = |q: f64| samples[(((samples.len() - 1) as f64) * q).round() as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: pct(0.5),
        p95: pct(0.95),
        min: samples[0],
    };
    println!(
        "{:<48} iters={:<5} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
        r.name, r.iters, r.mean, r.p50, r.p95, r.min
    );
    append_csv(&r);
    r
}

fn append_csv(r: &BenchResult) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results/bench.csv");
    let _ = std::fs::create_dir_all(path.parent().unwrap());
    let header_needed = !path.exists();
    let mut line = String::new();
    if header_needed {
        line.push_str("name,iters,mean_ns,p50_ns,p95_ns,min_ns\n");
    }
    line.push_str(&format!(
        "{},{},{},{},{},{}\n",
        r.name,
        r.iters,
        r.mean.as_nanos(),
        r.p50.as_nanos(),
        r.p95.as_nanos(),
        r.min.as_nanos()
    ));
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = f.write_all(line.as_bytes());
    }
}
