//! Scale proof for the hierarchical placement path: conservative
//! backfilling rounds over a ~100k-core resource tree. Builds the tree
//! directly in the database (default 16 switches × 400 hosts × 16 cores
//! = 102,400 cores over 6,400 hosts), pins half of every switch under
//! long-running blockers so the busy profile is real, then drives
//! scheduling rounds over a backlog mixing flat, switch-constrained and
//! moldable requests — applying each round's decision (reshape persist,
//! assignment, state walk to Running) before the next. Emits
//! `BENCH_hier.json` at the repo root: topology, per-round latency,
//! start/reshape counts and the sub-second verdict.
//!
//! Knobs: `OAR_HIER_SWITCHES` (16), `OAR_HIER_HOSTS` (hosts/switch,
//! 400), `OAR_HIER_CORES` (cores/host, 16), `OAR_HIER_JOBS` (waiting
//! jobs injected per round, 64), `OAR_HIER_ROUNDS` (5),
//! `OAR_HIER_BUDGET_MS` (per-round latency budget, 1000).
//!
//! The run doubles as a correctness gate: no round may reject a job,
//! every start's node count must match the (possibly reshaped) row, the
//! moldable fall-through must actually fire, and the views/indexes must
//! verify at the end; it exits non-zero otherwise.

use std::path::Path;
use std::time::Instant;

use oar::db::{Db, Value};
use oar::resources::Level;
use oar::sched::MetaScheduler;
use oar::types::{Job, JobSpec, JobState, Node, Time};
use oar::util::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|n| *n > 0)
        .unwrap_or(default)
}

struct Topology {
    switches: usize,
    hosts: usize, // per switch
    cores: usize, // per host
}

impl Topology {
    fn total_hosts(&self) -> usize {
        self.switches * self.hosts
    }
    fn total_cores(&self) -> usize {
        self.total_hosts() * self.cores
    }
}

/// Build the resource tree straight into the database: cluster root,
/// switch rows, and per host the host/cpu/core rows plus the derived
/// nodes-table row the scheduler reads (same layout
/// `VirtualCluster::register` produces, at a size no fixture has).
fn build_tree(db: &mut Db, topo: &Topology) {
    let root = db.add_resource(Level::Cluster, None, "bench", None);
    let mut id = 0u64;
    for s in 0..topo.switches {
        let sw = format!("sw{}", s + 1);
        let sw_id = db.add_resource(Level::Switch, Some(root), &sw, None);
        for h in 0..topo.hosts {
            id += 1;
            let name = format!("h{}-{h}", s + 1);
            let host = db.add_resource(Level::Host, Some(sw_id), &name, Some(id));
            let cpu = db.add_resource(Level::Cpu, Some(host), &format!("{name}-cpu0"), None);
            for c in 0..topo.cores {
                db.add_resource(Level::Core, Some(cpu), &format!("{name}-core{c}"), None);
            }
            db.add_node(
                Node::new(id, &name, topo.cores as u32)
                    .with_prop("switch", Value::Text(sw.clone())),
            );
        }
    }
}

/// Pin half of every switch under a Running blocker with a staggered
/// walltime, so backfilling scans a non-trivial busy profile instead of
/// an empty diagram.
fn pin_blockers(db: &mut Db, topo: &Topology) {
    let half = (topo.hosts / 2).max(1);
    for s in 0..topo.switches {
        let walltime = 1800 + (s % 4) as Time * 600;
        let spec = JobSpec {
            weight: topo.cores as u32,
            ..JobSpec::batch("blocker", "hold", half as u32, walltime)
        };
        let id = db.insert_job(Job::from_spec(&spec, 0));
        let first = (s * topo.hosts) as u64 + 1;
        let nodes: Vec<u64> = (first..first + half as u64).collect();
        db.assign_nodes(id, &nodes, topo.cores as u32);
        for state in [JobState::ToLaunch, JobState::Launching, JobState::Running] {
            db.set_job_state(id, state, 0).expect("blocker state walk");
        }
    }
}

/// One round's backlog: flat, switch-constrained and moldable requests
/// in rotation. The moldable shape's first alternative asks for more
/// cores per host than any host has, so the scheduler must fall through
/// — every round proves the reshape path at scale.
fn inject_backlog(db: &mut Db, topo: &Topology, jobs: usize, now: Time) {
    let cores = topo.cores as u32;
    for i in 0..jobs {
        let spec = match i % 3 {
            0 => JobSpec {
                weight: cores,
                ..JobSpec::batch("flat", "mpi", 8, 600)
            },
            1 => JobSpec {
                weight: cores,
                resources: Some(format!("/switch=2/host=8/core={cores}")),
                ..JobSpec::batch("locality", "mpi", 16, 600)
            },
            _ => JobSpec {
                weight: cores.saturating_mul(2),
                resources: Some(format!(
                    "/host=4/core={} | /host=8/core={cores}",
                    cores.saturating_mul(2)
                )),
                ..JobSpec::batch("moldable", "mpi", 4, 600)
            },
        };
        db.insert_job(Job::from_spec(&spec, now));
    }
}

fn main() {
    let topo = Topology {
        switches: env_usize("OAR_HIER_SWITCHES", 16),
        hosts: env_usize("OAR_HIER_HOSTS", 400),
        cores: env_usize("OAR_HIER_CORES", 16),
    };
    let jobs = env_usize("OAR_HIER_JOBS", 64);
    let rounds = env_usize("OAR_HIER_ROUNDS", 5);
    let budget_ms = env_usize("OAR_HIER_BUDGET_MS", 1000) as f64;

    println!(
        "== hier: {} switches x {} hosts x {} cores = {} cores over {} hosts ==",
        topo.switches,
        topo.hosts,
        topo.cores,
        topo.total_cores(),
        topo.total_hosts(),
    );

    let mut db = Db::with_standard_queues();
    let t0 = Instant::now();
    build_tree(&mut db, &topo);
    println!(
        "tree built in {:?} ({} resource rows)",
        t0.elapsed(),
        db.resource_count()
    );
    let hier = db.hierarchy();
    let mut ok = true;
    if hier.host_count() != topo.total_hosts() || hier.core_count() != topo.total_cores() as u64 {
        eprintln!(
            "GATE: hierarchy mismatch: {} hosts / {} cores",
            hier.host_count(),
            hier.core_count()
        );
        ok = false;
    }
    pin_blockers(&mut db, &topo);

    let mut meta = MetaScheduler::sql_only();
    let mut points = Vec::new();
    let mut latencies_ms = Vec::new();
    let mut total_starts = 0usize;
    let mut total_reshapes = 0usize;
    let mut now: Time = 10;

    for round in 0..rounds {
        inject_backlog(&mut db, &topo, jobs, now);

        let t = Instant::now();
        let d = meta.round(&db, now).expect("scheduling round");
        let ms = t.elapsed().as_secs_f64() * 1e3;
        latencies_ms.push(ms);

        if !d.rejected.is_empty() {
            eprintln!("GATE: round {round} rejected {:?}", d.rejected);
            ok = false;
        }
        // Apply the decision the way the server does: reshape rows
        // first, then assign and walk the started jobs to Running.
        for (id, nb, w) in &d.reshapes {
            db.set_job_shape(*id, *nb, *w).expect("persist reshape");
        }
        for (id, nodes) in &d.starts {
            let job = db.job(*id).expect("started job row");
            if nodes.len() as u32 != job.nb_nodes {
                eprintln!(
                    "GATE: round {round} job {id}: {} nodes vs nbNodes={}",
                    nodes.len(),
                    job.nb_nodes
                );
                ok = false;
            }
            db.assign_nodes(*id, nodes, job.weight);
            for state in [JobState::ToLaunch, JobState::Launching, JobState::Running] {
                db.set_job_state(*id, state, now).expect("start state walk");
            }
        }
        total_starts += d.starts.len();
        total_reshapes += d.reshapes.len();

        println!(
            "  round {round}: {ms:>8.2} ms  ({} starts, {} reshapes, {} waiting injected)",
            d.starts.len(),
            d.reshapes.len(),
            jobs
        );
        points.push(Json::obj(vec![
            ("round", Json::Num(round as f64)),
            ("ms", Json::Num(ms)),
            ("starts", Json::Num(d.starts.len() as f64)),
            ("reshapes", Json::Num(d.reshapes.len() as f64)),
        ]));
        now += 60;
    }

    if total_starts == 0 {
        eprintln!("GATE: no job ever started");
        ok = false;
    }
    if total_reshapes == 0 {
        eprintln!("GATE: the moldable fall-through never fired");
        ok = false;
    }
    if !db.verify_indexes() || !db.verify_views() {
        eprintln!("GATE: views/indexes failed verification after the run");
        ok = false;
    }

    let mean = latencies_ms.iter().sum::<f64>() / latencies_ms.len().max(1) as f64;
    let max = latencies_ms.iter().copied().fold(0.0f64, f64::max);
    let sub_second = max < budget_ms;
    println!(
        "\nround latency over {} cores: mean {mean:.2} ms, max {max:.2} ms (budget {budget_ms} ms) → {}",
        topo.total_cores(),
        if sub_second { "ok" } else { "OVER BUDGET" },
    );

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hier.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("hier".into())),
        (
            "topology",
            Json::obj(vec![
                ("switches", Json::Num(topo.switches as f64)),
                ("hosts_per_switch", Json::Num(topo.hosts as f64)),
                ("cores_per_host", Json::Num(topo.cores as f64)),
                ("total_hosts", Json::Num(topo.total_hosts() as f64)),
                ("total_cores", Json::Num(topo.total_cores() as f64)),
            ]),
        ),
        ("jobs_per_round", Json::Num(jobs as f64)),
        ("rounds", Json::Arr(points)),
        ("round_ms_mean", Json::Num(mean)),
        ("round_ms_max", Json::Num(max)),
        ("budget_ms", Json::Num(budget_ms)),
        ("sub_second", Json::Bool(sub_second)),
        ("total_starts", Json::Num(total_starts as f64)),
        ("total_reshapes", Json::Num(total_reshapes as f64)),
    ]);
    std::fs::write(&out, doc.dump()).expect("write BENCH_hier.json");
    println!("wrote {}", out.display());

    if !ok || !sub_second {
        eprintln!("HIER VERIFICATION FAILED");
        std::process::exit(1);
    }
}
