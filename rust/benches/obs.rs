//! Observability ablation bench: what does the telemetry layer cost?
//!
//! Two measurements, both against the runtime kill switch
//! (`obs::set_enabled`), which leaves the same single predictable branch
//! in place that the `obs_noop` feature folds to `false` at compile
//! time (run with `--features obs_noop` for the true compiled-out
//! baseline — the JSON records which mode measured):
//!
//! 1. **Micro**: ns/op for the three record primitives (counter inc,
//!    histogram observe, span enter+drop), enabled vs disabled.
//! 2. **Macro**: end-to-end scheduler throughput (submit a batch, drain
//!    to terminal) with instrumentation on vs off, interleaved rounds,
//!    medians. The per-phase spans, guard-wait histograms and round
//!    counters all fire on this path.
//!
//! Emits `BENCH_obs.json` at the repo root and exits non-zero when the
//! macro overhead exceeds the gate (`OAR_OBS_MAX_OVERHEAD_PCT`, default
//! 2.0) — the ISSUE's acceptance bound.
//!
//! Knobs: `OAR_OBS_JOBS` (jobs per macro round, default 400),
//! `OAR_OBS_ROUNDS` (interleaved round pairs, default 5),
//! `OAR_OBS_MICRO_OPS` (ops per micro loop, default 2,000,000).

use std::hint::black_box;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use oar::cluster::VirtualCluster;
use oar::obs;
use oar::server::{Server, ServerConfig};
use oar::types::JobSpec;
use oar::util::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn median(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite sample"));
    xs[xs.len() / 2]
}

// ------------------------------------------------------------- micro ----

static MICRO_C: obs::Counter = obs::Counter::new("bench_micro_total");
static MICRO_H: obs::Histogram = obs::Histogram::new("bench_micro_us", "us");
static MICRO_S: obs::Histogram = obs::Histogram::new("bench_micro_span_us", "us");

/// ns/op of `f` repeated `ops` times.
fn time_ns(ops: usize, mut f: impl FnMut(u64)) -> f64 {
    let t0 = Instant::now();
    for i in 0..ops {
        f(black_box(i as u64));
    }
    t0.elapsed().as_nanos() as f64 / ops.max(1) as f64
}

fn micro(ops: usize, enabled: bool) -> Json {
    obs::set_enabled(enabled);
    let counter = time_ns(ops, |_| MICRO_C.inc());
    let hist = time_ns(ops, |i| MICRO_H.observe(i % 4096));
    // Spans push into the ring mutex on drop; measure the full RAII
    // round-trip, which is what an instrumented region actually pays.
    let span = time_ns(ops / 16, |_| {
        let _s = obs::Span::enter("bench.micro", &MICRO_S);
    });
    obs::set_enabled(true);
    Json::obj(vec![
        ("counter_inc_ns", Json::Num(counter)),
        ("hist_observe_ns", Json::Num(hist)),
        ("span_ns", Json::Num(span)),
    ])
}

// ------------------------------------------------------------- macro ----

/// One macro round: fresh volatile server, submit `jobs`, drain to
/// terminal. Returns (jobs/sec, verified).
fn macro_round(jobs: usize, enabled: bool) -> (f64, bool) {
    obs::set_enabled(enabled);
    let cluster = Arc::new(VirtualCluster::tiny(8, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));

    let t0 = Instant::now();
    let mut acked = 0usize;
    for i in 0..jobs {
        let spec = JobSpec::batch("obs", "date", 1 + (i % 2) as u32, 60);
        if let Ok(Ok(_)) = server.submit(&spec) {
            acked += 1;
        }
        if i % 64 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let drained = server.wait_all_terminal(Duration::from_secs(120));
    let wall = t0.elapsed();
    obs::set_enabled(true);

    let db_jobs = server.read_db(|db| db.job_count());
    let ok = drained && acked == jobs && db_jobs == jobs;
    (jobs as f64 / wall.as_secs_f64().max(1e-9), ok)
}

fn main() {
    let jobs = env_usize("OAR_OBS_JOBS", 400);
    let rounds = env_usize("OAR_OBS_ROUNDS", 5);
    let micro_ops = env_usize("OAR_OBS_MICRO_OPS", 2_000_000);
    let max_overhead = env_f64("OAR_OBS_MAX_OVERHEAD_PCT", 2.0);
    let compiled_out = cfg!(feature = "obs_noop");
    println!(
        "== obs ablation: {rounds}x{jobs}-job rounds, {micro_ops} micro ops, gate {max_overhead}% \
         (mode: {}) ==\n",
        if compiled_out { "compiled-out (obs_noop)" } else { "runtime switch" }
    );

    // Micro: warm both paths once, then measure.
    let _ = micro(micro_ops / 10, true);
    let micro_on = micro(micro_ops, true);
    let micro_off = micro(micro_ops, false);
    println!("  micro enabled:  {}", micro_on.dump());
    println!("  micro disabled: {}", micro_off.dump());

    // Macro: interleave on/off rounds so machine drift cancels; one
    // throwaway warmup round first.
    let _ = macro_round(jobs / 4, true);
    let mut on = Vec::new();
    let mut off = Vec::new();
    let mut all_ok = true;
    for r in 0..rounds {
        let (tp_on, ok_on) = macro_round(jobs, true);
        let (tp_off, ok_off) = macro_round(jobs, false);
        all_ok &= ok_on && ok_off;
        println!(
            "  round {r}: {tp_on:>8.0} jobs/s instrumented   {tp_off:>8.0} jobs/s ablated  \
             ({})",
            if ok_on && ok_off { "ok" } else { "FAILED" }
        );
        on.push(tp_on);
        off.push(tp_off);
    }
    let med_on = median(&mut on);
    let med_off = median(&mut off);
    // Overhead of instrumentation relative to the ablated baseline;
    // negative (noise) clamps to zero.
    let overhead_pct = ((med_off / med_on.max(1e-9) - 1.0) * 100.0).max(0.0);
    println!(
        "\n  median: {med_on:.0} jobs/s instrumented vs {med_off:.0} ablated \
         -> overhead {overhead_pct:.2}% (gate {max_overhead}%)"
    );

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_obs.json");
    let doc = Json::obj(vec![
        ("bench", Json::Str("obs".into())),
        (
            "mode",
            Json::Str(if compiled_out { "compiled_out" } else { "runtime_switch" }.into()),
        ),
        ("jobs_per_round", Json::Num(jobs as f64)),
        ("rounds", Json::Num(rounds as f64)),
        ("micro_ops", Json::Num(micro_ops as f64)),
        (
            "micro_ns_per_op",
            Json::obj(vec![("enabled", micro_on), ("disabled", micro_off)]),
        ),
        (
            "macro_jobs_per_sec",
            Json::obj(vec![
                (
                    "instrumented",
                    Json::Arr(on.iter().map(|v| Json::Num(*v)).collect()),
                ),
                (
                    "ablated",
                    Json::Arr(off.iter().map(|v| Json::Num(*v)).collect()),
                ),
                ("median_instrumented", Json::Num(med_on)),
                ("median_ablated", Json::Num(med_off)),
            ]),
        ),
        ("overhead_pct", Json::Num(overhead_pct)),
        ("max_overhead_pct", Json::Num(max_overhead)),
        (
            "verified",
            Json::obj(vec![("workloads_ok", Json::Bool(all_ok))]),
        ),
    ]);
    std::fs::write(&out, doc.dump()).expect("write BENCH_obs.json");
    println!("wrote {}", out.display());

    if !all_ok {
        eprintln!("OBS ABLATION VERIFICATION FAILED (workload correctness)");
        std::process::exit(1);
    }
    if overhead_pct > max_overhead {
        eprintln!("OBS OVERHEAD GATE FAILED: {overhead_pct:.2}% > {max_overhead}%");
        std::process::exit(1);
    }
}
