//! Ablation A3 (DESIGN.md §6): the central module's notification path —
//! coalescing hub throughput, and end-to-end scheduling-round latency of
//! the meta-scheduler over a loaded database (the paper's reactivity
//! argument, §2.2).

mod common;

use common::bench;
use oar::central::{NotificationHub, Task};
use oar::db::Db;
use oar::matching::ReferenceStep;
use oar::sched::{MetaScheduler, SchedulerConfig};
use oar::types::{Job, JobSpec, Node};

fn main() {
    println!("== central: notification hub ==");
    let hub = NotificationHub::new();
    bench("notify_coalesced/1000", 10, 100, || {
        for _ in 0..1000 {
            hub.notify(Task::Schedule);
        }
        hub.poll()
    });

    println!("\n== meta-scheduler round latency (dense vs sql matching) ==");
    for waiting in [16usize, 64, 256] {
        for dense in [false, true] {
            let mut db = Db::with_standard_queues();
            for i in 1..=34u32 {
                db.add_node(
                    Node::new(i, &format!("n{i}"), 1)
                        .with_prop("mem", oar::db::Value::Int(512))
                        .with_prop("cpu_mhz", oar::db::Value::Int(2400)),
                );
            }
            for i in 0..waiting {
                let spec = JobSpec::batch(
                    &format!("u{}", i % 8),
                    "date",
                    1 + (i % 4) as u32,
                    600,
                );
                db.insert_job(Job::from_spec(&spec, i as i64));
            }
            let mut meta = MetaScheduler::new(
                SchedulerConfig {
                    dense_matching: dense,
                    ..Default::default()
                },
                Box::new(ReferenceStep),
            );
            let label = if dense { "dense" } else { "sql" };
            bench(
                &format!("meta_round/{waiting}_waiting_{label}"),
                2,
                20,
                || meta.round(&mut db, 0).unwrap().starts.len(),
            );
        }
    }
}
